//! Unified engine configuration: one typed builder (and one textual
//! grammar) that subsumes the old closed `EngineKind` enum and its ad-hoc
//! `(Multiplier, threads)` tuple plumbing.
//!
//! An [`EngineConfig`] names *which* kernel executes each layer (or
//! `auto`, letting the planner choose per layer from the theory model),
//! *what* multiplier it packs for, and every tuning knob that used to be
//! hard-coded: thread budget, operand bitwidths/signedness, output-channel
//! tile depth, channel-block depth, and the word-lane width the plan's
//! theory bound is reported against (engines select their own `i64` /
//! `i128` lane automatically).
//!
//! # Grammar
//!
//! The same spelling is accepted by `--engine`/`--backend` on the CLI and
//! by serve configs, and is emitted by [`Display`](std::fmt::Display) so
//! bench labels and parsed configs can never drift (property-tested
//! round-trip in `tests/engine_config.rs`):
//!
//! ```text
//! <kernel>[@<A>x<B>][:<key>=<value>[,<key>=<value>]*]
//!
//! kernel:  auto | baseline | hikonv | hikonv-tiled | im2row | ...
//! @AxB:    multiplier ports (default 32x32; named aliases cpu32, cpu64,
//!          dsp48e2 also parse)
//! keys:    threads=N    intra-layer tiling threads (0 = auto-size)
//!          p=N,q=N      operand bitwidth override (must appear together;
//!                       default: per-layer a_bits/w_bits)
//!          sign=u|s|us  operand signedness (default us: unsigned
//!                       activations x signed weights)
//!          tile-co=N    output-channel tile depth override
//!          block=N      channel-block depth override (conv2d engine)
//!          lane=N       word-lane width the reported lane bound is
//!                       solved against (default 64, the i64 fast lane)
//!          probe        enable the measured calibration probe in `auto`
//!                       planning (selection is then timing-based, not
//!                       deterministic)
//! ```
//!
//! Examples: `auto`, `hikonv-tiled:threads=4`, `im2row@32x32:tile-co=8`,
//! `hikonv@27x18:p=4,q=4,sign=u`.

use crate::theory::{Multiplier, Signedness, FAST_LANE_BITS};
use std::fmt;
use std::str::FromStr;

/// Which kernel the runner binds per layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum KernelChoice {
    /// Let the planner score every registered kernel per layer and pick
    /// the predicted-fastest one ([`EnginePlan`](super::EnginePlan)).
    Auto,
    /// One named kernel (a [`KernelRegistry`](super::KernelRegistry)
    /// entry) for every layer.
    Named(String),
}

/// Unified engine configuration (see the module docs for the grammar).
///
/// Build with [`EngineConfig::auto`] / [`EngineConfig::named`] plus the
/// `with_*` builder methods, or parse the textual form via [`FromStr`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EngineConfig {
    /// Kernel selection: `auto` or one registry name for all layers.
    pub kernel: KernelChoice,
    /// The multiplier the engines pack for (default [`Multiplier::CPU32`]).
    pub mult: Multiplier,
    /// Intra-layer tiling threads (0 = auto-size from the machine /
    /// `HIKONV_THREADS`).
    pub threads: usize,
    /// Operand signedness (default: unsigned activations x signed
    /// weights, the common quantized-DNN case).
    pub signedness: Signedness,
    /// Operand bitwidth override `(p, q)`; `None` uses each layer's own
    /// `a_bits`/`w_bits`.
    pub bits: Option<(u32, u32)>,
    /// Output-channel tile depth override; `None` uses the
    /// [`tile_co_for`](super::tile_co_for) heuristic.
    pub tile_co: Option<usize>,
    /// Channel-block depth override for the Thm.-3 conv2d engine; `None`
    /// lets the engine's cost model choose (clamped to the layer's `ci`).
    pub channel_block: Option<usize>,
    /// Software word-lane width in bits the planner's reported
    /// lane-bound column is solved against (64 = the `i64` fast lane).
    /// The engines select their own lane automatically
    /// ([`DesignPoint::fits_lane`](crate::theory::DesignPoint::fits_lane)
    /// at 64 bits), and the cost models penalize points that fall off
    /// that real lane regardless of this setting.
    pub lane_bits: u32,
    /// Run the measured calibration probe during `auto` planning and
    /// select by observed time instead of the deterministic cost model.
    pub probe: bool,
}

impl Default for EngineConfig {
    /// The old default engine: serial HiKonv packing on a 32x32 ALU.
    fn default() -> EngineConfig {
        EngineConfig::named("hikonv")
    }
}

impl EngineConfig {
    /// Planner-driven configuration: every layer gets the registered
    /// kernel the theory model predicts fastest on this host.
    pub fn auto() -> EngineConfig {
        EngineConfig {
            kernel: KernelChoice::Auto,
            mult: Multiplier::CPU32,
            threads: 0,
            signedness: Signedness::UnsignedBySigned,
            bits: None,
            tile_co: None,
            channel_block: None,
            lane_bits: 64,
            probe: false,
        }
    }

    /// One named kernel for every layer (validated against the registry
    /// when a plan or runner is built).
    pub fn named(name: &str) -> EngineConfig {
        EngineConfig {
            kernel: KernelChoice::Named(name.to_string()),
            ..EngineConfig::auto()
        }
    }

    /// The named kernel, or `None` for `auto`.
    pub fn kernel_name(&self) -> Option<&str> {
        match &self.kernel {
            KernelChoice::Auto => None,
            KernelChoice::Named(n) => Some(n),
        }
    }

    pub fn with_multiplier(mut self, mult: Multiplier) -> EngineConfig {
        self.mult = mult;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> EngineConfig {
        self.threads = threads;
        self
    }

    pub fn with_signedness(mut self, signedness: Signedness) -> EngineConfig {
        self.signedness = signedness;
        self
    }

    pub fn with_bits(mut self, p: u32, q: u32) -> EngineConfig {
        self.bits = Some((p, q));
        self
    }

    pub fn with_tile_co(mut self, tile_co: usize) -> EngineConfig {
        self.tile_co = Some(tile_co);
        self
    }

    pub fn with_channel_block(mut self, block: usize) -> EngineConfig {
        self.channel_block = Some(block);
        self
    }

    pub fn with_lane_bits(mut self, lane_bits: u32) -> EngineConfig {
        self.lane_bits = lane_bits;
        self
    }

    pub fn with_probe(mut self, probe: bool) -> EngineConfig {
        self.probe = probe;
        self
    }

    /// The operand bitwidths for a layer quantized to `a_bits`/`w_bits`:
    /// the config override when set, the layer's own widths otherwise.
    pub fn layer_bits(&self, a_bits: u32, w_bits: u32) -> (u32, u32) {
        self.bits.unwrap_or((a_bits, w_bits))
    }

    /// The fast-lane budget cost models and feasibility hooks select
    /// against: the configured `lane=` bound, capped at the engines'
    /// actual `i64` fast path ([`FAST_LANE_BITS`]). A wider configured
    /// lane (e.g. `lane=128`) does not make the `i64` word any wider, so
    /// the cap keeps predicted costs honest; a narrower one tightens the
    /// budget (and the verifier enforces it as a hard `V-LANE` bound).
    pub fn fast_lane_bits(&self) -> u32 {
        self.lane_bits.min(FAST_LANE_BITS)
    }
}

impl fmt::Display for EngineConfig {
    /// The canonical grammar spelling; parsing it back yields an equal
    /// config (round-trip property-tested). Defaults are omitted.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.kernel {
            KernelChoice::Auto => f.write_str("auto")?,
            KernelChoice::Named(n) => f.write_str(n)?,
        }
        if self.mult != Multiplier::CPU32 {
            write!(f, "@{}", self.mult)?;
        }
        let mut params: Vec<String> = Vec::new();
        if self.threads != 0 {
            params.push(format!("threads={}", self.threads));
        }
        if let Some((p, q)) = self.bits {
            params.push(format!("p={p}"));
            params.push(format!("q={q}"));
        }
        if self.signedness != Signedness::UnsignedBySigned {
            params.push(format!("sign={}", self.signedness));
        }
        if let Some(t) = self.tile_co {
            params.push(format!("tile-co={t}"));
        }
        if let Some(b) = self.channel_block {
            params.push(format!("block={b}"));
        }
        if self.lane_bits != 64 {
            params.push(format!("lane={}", self.lane_bits));
        }
        if self.probe {
            params.push("probe".to_string());
        }
        if !params.is_empty() {
            write!(f, ":{}", params.join(","))?;
        }
        Ok(())
    }
}

fn parse_val<T: FromStr>(spec: &str, key: &str, val: &str) -> Result<T, String> {
    val.trim()
        .parse()
        .map_err(|_| format!("engine spec '{spec}': bad value '{val}' for '{key}'"))
}

impl FromStr for EngineConfig {
    type Err = String;

    fn from_str(s: &str) -> Result<EngineConfig, String> {
        let spec = s.trim();
        if spec.is_empty() {
            return Err("empty engine spec".to_string());
        }
        let (head, params) = match spec.split_once(':') {
            Some((h, p)) => (h, Some(p)),
            None => (spec, None),
        };
        let (name, mult) = match head.split_once('@') {
            Some((n, m)) => (n.trim(), m.parse::<Multiplier>()?),
            None => (head.trim(), Multiplier::CPU32),
        };
        if name.is_empty() {
            return Err(format!("engine spec '{spec}': missing kernel name"));
        }
        let mut cfg = if name == "auto" {
            EngineConfig::auto()
        } else {
            EngineConfig::named(name)
        };
        cfg.mult = mult;
        let (mut p_bits, mut q_bits) = (None, None);
        for item in params.unwrap_or("").split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            let (key, val) = match item.split_once('=') {
                Some((k, v)) => (k.trim(), Some(v)),
                None => (item, None),
            };
            match (key, val) {
                ("probe", None) => cfg.probe = true,
                ("probe", Some(v)) => cfg.probe = parse_val(spec, key, v)?,
                ("threads", Some(v)) => cfg.threads = parse_val(spec, key, v)?,
                ("p", Some(v)) => p_bits = Some(parse_val::<u32>(spec, key, v)?),
                ("q", Some(v)) => q_bits = Some(parse_val::<u32>(spec, key, v)?),
                ("sign", Some(v)) => cfg.signedness = v.trim().parse()?,
                ("tile-co", Some(v)) => cfg.tile_co = Some(parse_val(spec, key, v)?),
                ("block", Some(v)) => cfg.channel_block = Some(parse_val(spec, key, v)?),
                ("lane", Some(v)) => cfg.lane_bits = parse_val(spec, key, v)?,
                ("threads" | "p" | "q" | "sign" | "tile-co" | "block" | "lane", None) => {
                    return Err(format!(
                        "engine spec '{spec}': parameter '{key}' needs a value"
                    ));
                }
                (other, _) => {
                    return Err(format!(
                        "engine spec '{spec}': unknown parameter '{other}' \
                         (known: threads, p, q, sign, tile-co, block, lane, probe)"
                    ));
                }
            }
        }
        match (p_bits, q_bits) {
            (None, None) => {}
            (Some(p), Some(q)) => cfg.bits = Some((p, q)),
            _ => {
                return Err(format!(
                    "engine spec '{spec}': p and q must be given together"
                ));
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_names_parse_with_defaults() {
        let cfg: EngineConfig = "hikonv".parse().unwrap();
        assert_eq!(cfg, EngineConfig::named("hikonv"));
        assert_eq!(cfg.mult, Multiplier::CPU32);
        assert_eq!(cfg.threads, 0);
        assert_eq!(cfg.lane_bits, 64);
        assert!(!cfg.probe);
        let auto: EngineConfig = "auto".parse().unwrap();
        assert_eq!(auto.kernel, KernelChoice::Auto);
        assert_eq!(auto.kernel_name(), None);
    }

    #[test]
    fn full_grammar_parses() {
        let cfg: EngineConfig =
            "hikonv-tiled@27x18:threads=4,p=3,q=5,sign=u,tile-co=8,block=2,lane=128,probe"
                .parse()
                .unwrap();
        assert_eq!(cfg.kernel_name(), Some("hikonv-tiled"));
        assert_eq!(cfg.mult, Multiplier::DSP48E2);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.bits, Some((3, 5)));
        assert_eq!(cfg.signedness, Signedness::Unsigned);
        assert_eq!(cfg.tile_co, Some(8));
        assert_eq!(cfg.channel_block, Some(2));
        assert_eq!(cfg.lane_bits, 128);
        assert!(cfg.probe);
    }

    #[test]
    fn display_omits_defaults_and_round_trips() {
        assert_eq!(EngineConfig::named("im2row").to_string(), "im2row");
        assert_eq!(EngineConfig::auto().to_string(), "auto");
        let cfg = EngineConfig::named("hikonv-tiled")
            .with_threads(4)
            .with_multiplier(Multiplier::CPU64)
            .with_tile_co(8);
        let rendered = cfg.to_string();
        assert_eq!(rendered, "hikonv-tiled@64x64:threads=4,tile-co=8");
        assert_eq!(rendered.parse::<EngineConfig>().unwrap(), cfg);
    }

    #[test]
    fn bad_specs_error() {
        assert!("".parse::<EngineConfig>().is_err());
        assert!("@32x32".parse::<EngineConfig>().is_err());
        assert!("hikonv:frobs=2".parse::<EngineConfig>().is_err());
        assert!("hikonv:threads=abc".parse::<EngineConfig>().is_err());
        assert!("hikonv:p=4".parse::<EngineConfig>().is_err(), "p without q");
        assert!("hikonv@1y1".parse::<EngineConfig>().is_err());
    }

    #[test]
    fn layer_bits_prefers_override() {
        assert_eq!(EngineConfig::auto().layer_bits(4, 4), (4, 4));
        assert_eq!(EngineConfig::auto().with_bits(2, 3).layer_bits(4, 4), (2, 3));
    }
}
