//! The [`ConvKernel`] capability trait: one object-safe contract that
//! unifies the per-engine triplicate surfaces the runner, coordinator and
//! CLI used to wire by hand (`conv2d_tiled`/`im2row_tiled`, their `_into`
//! twins, and the pooled wrapper structs).
//!
//! A kernel is a layer-level convolution engine with bound weights. It
//! executes through exactly two entry points — an allocation-lean
//! [`conv_into`](ConvKernel::conv_into) that the fused arena pipeline
//! drives, and an allocating [`conv`](ConvKernel::conv) convenience used
//! by calibration and the seed/unfused oracle path — and owns its
//! per-frame working state behind an opaque [`KernelScratch`] so arenas
//! can pool it without knowing any kernel's internals. New backends
//! implement this trait and register a factory
//! ([`KernelFactory`](super::KernelFactory)) instead of being threaded
//! through runner, coordinator, server and `main.rs` by hand.

use super::{conv2d_tiled_into_depth, im2row_tiled_into_depth, tile_co_for, PAR_MIN_MACS};
use crate::conv::conv2d::{Conv2dHiKonv, PackedInput};
use crate::conv::gemm::PackedLhs;
use crate::conv::im2row::Im2RowConv;
use crate::conv::reference::{conv2d_ref_into, conv2d_ref_strided_into, strided_out, ConvShape};
use crate::exec::ThreadPool;
use std::any::Any;

/// Opaque per-frame working state of one kernel instance (packed words,
/// gather/segmentation buffers, …). Created once per arena via
/// [`ConvKernel::new_scratch`] and reused across frames, so steady-state
/// execution allocates nothing; each kernel downcasts its own type back.
pub type KernelScratch = Box<dyn Any + Send>;

/// One kernel's weight memory in its construction-time layout — the unit
/// of weight storage in AOT compiled-model artifacts ([`crate::artifact`]).
///
/// Exported from a built kernel via [`ConvKernel::packed_weights`] and
/// fed back through
/// [`KernelFactory::build_from_packed`](super::KernelFactory::build_from_packed),
/// which reconstructs the kernel **without repacking** (the skipped work
/// AOT loading exists to skip). Word lanes follow the engines' own
/// selection: only the lane `DesignPoint::fits_lane(FAST_LANE_BITS)` picks is
/// populated.
#[derive(Clone, Debug)]
pub enum PackedWeights {
    /// Raw widened weight levels `[co][ci][kh][kw]` — kernels that do no
    /// packing (the baseline 6-loop nest).
    Raw(Vec<i64>),
    /// Thm.-3 overlap-add engine words: the solved channel block plus one
    /// packed (reversed) weight-row word per `(co, ci, kh)`.
    HiKonv {
        /// Channels accumulated per packed-domain block (the design
        /// point is re-solved from this, so it need not be stored).
        channel_block: usize,
        /// `i64`-lane words (empty when the point needs the wide lane).
        words64: Vec<i64>,
        /// `i128`-lane words (empty on the fast lane).
        words128: Vec<i128>,
    },
    /// Pre-packed GEMM right operand, word-major `[word][col]` (the
    /// im2row / FC lowering).
    Gemm {
        /// `i64`-lane words (empty when the point needs the wide lane).
        words64: Vec<i64>,
        /// `i128`-lane words (empty on the fast lane).
        words128: Vec<i128>,
    },
}

/// A layer-level convolution kernel with bound weights — the one
/// object-safe contract every backend implements.
pub trait ConvKernel: Send + Sync {
    /// Registry name of the kernel that built this instance.
    fn name(&self) -> &'static str;

    /// The (padded) stride-1 layer shape this kernel was built for.
    fn shape(&self) -> ConvShape;

    /// Output sampling stride (1 = dense). Strided units built on
    /// stride-1-native engines subsample internally.
    fn stride(&self) -> usize {
        1
    }

    /// Strided output spatial dims (`(shape().ho(), shape().wo())` at
    /// stride 1).
    fn out_dims(&self) -> (usize, usize) {
        strided_out(self.shape(), self.stride())
    }

    /// Flat output length (`co·ho_s·wo_s`) — the buffer size
    /// [`conv_into`](Self::conv_into) expects.
    fn out_len(&self) -> usize {
        let (h, w) = self.out_dims();
        self.shape().co * h * w
    }

    /// Fresh per-arena scratch for this kernel.
    fn new_scratch(&self) -> KernelScratch;

    /// Execute the layer on `[ci][h][w]` activations into a
    /// caller-provided buffer ([`out_len`](Self::out_len) values,
    /// overwritten). `scratch` must come from
    /// [`new_scratch`](Self::new_scratch) on the same instance; `pool` is
    /// the intra-layer tiling pool (`None` or a 1-thread pool means
    /// serial — kernels may also ignore it entirely). With a warmed
    /// scratch the serial paths perform zero heap allocations.
    fn conv_into(
        &self,
        input: &[i64],
        out: &mut [i64],
        scratch: &mut KernelScratch,
        pool: Option<&ThreadPool>,
    );

    /// Allocating convenience path (fresh scratch + fresh output) — what
    /// calibration and the seed/unfused oracle use.
    fn conv(&self, input: &[i64], pool: Option<&ThreadPool>) -> Vec<i64> {
        let mut out = vec![0i64; self.out_len()];
        let mut scratch = self.new_scratch();
        self.conv_into(input, &mut out, &mut scratch, pool);
        out
    }

    /// Export this kernel's weight memory for an AOT artifact
    /// ([`crate::artifact`]), in a form its factory's
    /// [`build_from_packed`](super::KernelFactory::build_from_packed)
    /// reconstructs without repacking. `None` (the default) means the
    /// backend does not participate in AOT compilation — `compile`
    /// reports a precise error instead of silently re-planning.
    fn packed_weights(&self) -> Option<PackedWeights> {
        None
    }
}

/// Copy every `stride`-th output pixel of a dense `[co][ho][wo]` map into
/// the strided `[co][ho_s][wo_s]` layout — the subsample adapter that
/// gives stride-1-native engines (the Thm.-3 overlap-add packing is
/// inherently dense along a row) exact strided semantics.
fn subsample_into(full: &[i64], sh: ConvShape, stride: usize, out: &mut [i64]) {
    let (ho, wo) = (sh.ho(), sh.wo());
    let (hs, ws) = strided_out(sh, stride);
    assert_eq!(full.len(), sh.co * ho * wo, "dense buffer length mismatch");
    assert_eq!(out.len(), sh.co * hs * ws, "strided buffer length mismatch");
    for co in 0..sh.co {
        for y in 0..hs {
            let src = (co * ho + y * stride) * wo;
            let dst = (co * hs + y) * ws;
            for x in 0..ws {
                out[dst + x] = full[src + x * stride];
            }
        }
    }
}

/// Baseline 6-loop kernel (Eq. 17) — the Fig. 6 reference. Strided units
/// run the strided reference loop directly (no dense intermediate).
pub struct BaselineKernel {
    shape: ConvShape,
    stride: usize,
    weights: Vec<i64>,
}

impl BaselineKernel {
    pub fn new(shape: ConvShape, weights: Vec<i64>) -> BaselineKernel {
        Self::with_stride(shape, weights, 1)
    }

    pub fn with_stride(shape: ConvShape, weights: Vec<i64>, stride: usize) -> BaselineKernel {
        assert_eq!(weights.len(), shape.weight_len(), "weight length mismatch");
        assert!(stride >= 1, "stride must be >= 1");
        BaselineKernel {
            shape,
            stride,
            weights,
        }
    }
}

impl ConvKernel for BaselineKernel {
    fn name(&self) -> &'static str {
        "baseline"
    }

    fn shape(&self) -> ConvShape {
        self.shape
    }

    fn stride(&self) -> usize {
        self.stride
    }

    fn new_scratch(&self) -> KernelScratch {
        Box::new(())
    }

    fn conv_into(
        &self,
        input: &[i64],
        out: &mut [i64],
        _scratch: &mut KernelScratch,
        _pool: Option<&ThreadPool>,
    ) {
        if self.stride == 1 {
            conv2d_ref_into(input, &self.weights, self.shape, out);
        } else {
            conv2d_ref_strided_into(input, &self.weights, self.shape, self.stride, out);
        }
    }

    fn packed_weights(&self) -> Option<PackedWeights> {
        Some(PackedWeights::Raw(self.weights.clone()))
    }
}

/// Per-arena working state of [`HiKonvKernel`].
struct HiKonvScratch {
    packed: PackedInput,
    seg: Vec<i64>,
    /// Dense stride-1 output for the subsample adapter (empty at
    /// stride 1, where the engine writes the caller's buffer directly).
    full: Vec<i64>,
}

/// HiKonv packed kernel (Thms. 1–3): serial, or with output channels
/// tiled across the pool (`tiled`) when a layer clears the
/// [`PAR_MIN_MACS`] cutoff. The overlap-add packing is dense along each
/// row, so strided units compute the full-resolution map into arena
/// scratch and subsample — exact, at dense cost (which the planner's
/// cost model charges, steering `auto` toward natively-strided kernels).
pub struct HiKonvKernel {
    inner: Conv2dHiKonv,
    tiled: bool,
    tile_co: Option<usize>,
    stride: usize,
}

impl HiKonvKernel {
    /// Wrap a built engine. `tile_co` overrides the
    /// [`tile_co_for`] heuristic when tiling.
    pub fn new(inner: Conv2dHiKonv, tiled: bool, tile_co: Option<usize>) -> HiKonvKernel {
        Self::with_stride(inner, tiled, tile_co, 1)
    }

    /// Wrap with an output sampling stride (subsample adapter).
    pub fn with_stride(
        inner: Conv2dHiKonv,
        tiled: bool,
        tile_co: Option<usize>,
        stride: usize,
    ) -> HiKonvKernel {
        assert!(stride >= 1, "stride must be >= 1");
        HiKonvKernel {
            inner,
            tiled,
            tile_co,
            stride,
        }
    }

    /// The wrapped Thm.-3 engine (design-point introspection).
    pub fn engine(&self) -> &Conv2dHiKonv {
        &self.inner
    }

    /// The dense stride-1 pass shared by both stride paths.
    fn dense_into(&self, s: &mut HiKonvScratch, out: &mut [i64], pool: Option<&ThreadPool>) {
        let sh = self.inner.shape();
        match pool {
            // The cutoff is applied here (not only inside the tiling entry
            // point) so sub-cutoff layers use the arena's segmentation
            // scratch instead of allocating one.
            Some(p) if self.tiled && p.threads() > 1 && sh.macs() >= PAR_MIN_MACS => {
                let depth = self
                    .tile_co
                    .unwrap_or_else(|| tile_co_for(sh.co, p.threads()));
                conv2d_tiled_into_depth(&self.inner, p, &s.packed, depth, out);
            }
            _ => {
                out.iter_mut().for_each(|v| *v = 0);
                self.inner
                    .conv_co_range_with(&s.packed, 0, sh.co, out, &mut s.seg);
            }
        }
    }
}

impl ConvKernel for HiKonvKernel {
    fn name(&self) -> &'static str {
        if self.tiled {
            "hikonv-tiled"
        } else {
            "hikonv"
        }
    }

    fn shape(&self) -> ConvShape {
        self.inner.shape()
    }

    fn stride(&self) -> usize {
        self.stride
    }

    fn new_scratch(&self) -> KernelScratch {
        let sh = self.inner.shape();
        let full = if self.stride == 1 {
            Vec::new()
        } else {
            vec![0i64; sh.output_len()]
        };
        Box::new(HiKonvScratch {
            packed: PackedInput::empty(),
            seg: vec![0i64; sh.wi + sh.k - 1],
            full,
        })
    }

    fn conv_into(
        &self,
        input: &[i64],
        out: &mut [i64],
        scratch: &mut KernelScratch,
        pool: Option<&ThreadPool>,
    ) {
        let s = scratch
            .downcast_mut::<HiKonvScratch>()
            .unwrap_or_else(|| unreachable!("scratch built by a different kernel"));
        self.inner.pack_input_into(input, &mut s.packed);
        if self.stride == 1 {
            self.dense_into(s, out, pool);
        } else {
            let sh = self.inner.shape();
            let mut full = std::mem::take(&mut s.full);
            self.dense_into(s, &mut full, pool);
            subsample_into(&full, sh, self.stride, out);
            s.full = full;
        }
    }

    fn packed_weights(&self) -> Option<PackedWeights> {
        let (w64, w128) = self.inner.packed_weight_words();
        Some(PackedWeights::HiKonv {
            channel_block: self.inner.channel_block(),
            words64: w64.to_vec(),
            words128: w128.to_vec(),
        })
    }
}

/// Per-arena working state of [`Im2RowKernel`].
struct Im2RowScratch {
    lhs: PackedLhs,
    row: Vec<i64>,
}

/// im2row/pre-packed-GEMM kernel: weights packed at construction,
/// activation rows streamed into packed words per frame, output-channel
/// tiles sharded across the pool when one is provided.
pub struct Im2RowKernel {
    inner: Im2RowConv,
    tile_co: Option<usize>,
}

impl Im2RowKernel {
    /// Wrap a built lowering. `tile_co` overrides the
    /// [`tile_co_for`] heuristic when tiling.
    pub fn new(inner: Im2RowConv, tile_co: Option<usize>) -> Im2RowKernel {
        Im2RowKernel { inner, tile_co }
    }

    /// The wrapped im2row/GEMM lowering (design-point introspection).
    pub fn engine(&self) -> &Im2RowConv {
        &self.inner
    }
}

impl ConvKernel for Im2RowKernel {
    fn name(&self) -> &'static str {
        "im2row"
    }

    fn shape(&self) -> ConvShape {
        self.inner.spec().shape
    }

    fn stride(&self) -> usize {
        self.inner.stride()
    }

    fn new_scratch(&self) -> KernelScratch {
        let sh = self.inner.spec().shape;
        Box::new(Im2RowScratch {
            lhs: self.inner.gemm().lhs_builder(self.inner.rows()),
            row: vec![0i64; sh.ci * sh.k * sh.k],
        })
    }

    fn conv_into(
        &self,
        input: &[i64],
        out: &mut [i64],
        scratch: &mut KernelScratch,
        pool: Option<&ThreadPool>,
    ) {
        let s = scratch
            .downcast_mut::<Im2RowScratch>()
            .unwrap_or_else(|| unreachable!("scratch built by a different kernel"));
        let sh = self.inner.spec().shape;
        self.inner.pack_pixels_into(input, &mut s.lhs, &mut s.row);
        match pool {
            Some(p) if p.threads() > 1 && sh.macs() >= PAR_MIN_MACS => {
                let depth = self
                    .tile_co
                    .unwrap_or_else(|| tile_co_for(sh.co, p.threads()));
                im2row_tiled_into_depth(&self.inner, p, &s.lhs, depth, out);
            }
            _ => self.inner.conv_cols(&s.lhs, 0, sh.co, out),
        }
    }

    fn packed_weights(&self) -> Option<PackedWeights> {
        let (w64, w128) = self.inner.gemm().packed_words();
        Some(PackedWeights::Gemm {
            words64: w64.to_vec(),
            words128: w128.to_vec(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d::Conv2dSpec;
    use crate::conv::reference::conv2d_ref;
    use crate::testing::assert_seq_eq;
    use crate::theory::{Multiplier, Signedness};
    use crate::util::rng::Rng;

    fn test_kernels(shape: ConvShape, weights: &[i64]) -> Vec<Box<dyn ConvKernel>> {
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        vec![
            Box::new(BaselineKernel::new(shape, weights.to_vec())),
            Box::new(HiKonvKernel::new(
                Conv2dHiKonv::new(spec, weights).unwrap(),
                false,
                None,
            )),
            Box::new(HiKonvKernel::new(
                Conv2dHiKonv::new(spec, weights).unwrap(),
                true,
                None,
            )),
            Box::new(Im2RowKernel::new(
                Im2RowConv::new(spec, weights).unwrap(),
                None,
            )),
        ]
    }

    #[test]
    fn every_kernel_agrees_with_the_reference_via_trait_objects() {
        let shape = ConvShape {
            ci: 5,
            co: 7,
            hi: 8,
            wi: 13,
            k: 3,
        };
        let mut rng = Rng::new(42);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let want = conv2d_ref(&input, &weights, shape);
        let pool = ThreadPool::new(3);
        for kernel in test_kernels(shape, &weights) {
            assert_seq_eq(&kernel.conv(&input, None), &want).unwrap();
            assert_seq_eq(&kernel.conv(&input, Some(&pool)), &want).unwrap();
            assert_eq!(kernel.shape(), shape);
        }
    }

    #[test]
    fn conv_into_with_reused_scratch_matches_conv() {
        // Large enough to clear the PAR_MIN_MACS cutoff so the pooled
        // branch genuinely runs.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(43);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let pool = ThreadPool::new(4);
        for kernel in test_kernels(shape, &weights) {
            let mut scratch = kernel.new_scratch();
            let mut out = vec![123i64; shape.output_len()];
            for _ in 0..3 {
                let input = rng.quant_unsigned_vec(4, shape.input_len());
                let want = conv2d_ref(&input, &weights, shape);
                out.iter_mut().for_each(|v| *v = 123); // stale contents overwritten
                kernel.conv_into(&input, &mut out, &mut scratch, Some(&pool));
                assert_seq_eq(&out, &want).unwrap();
                kernel.conv_into(&input, &mut out, &mut scratch, None);
                assert_seq_eq(&out, &want).unwrap();
            }
        }
    }

    #[test]
    fn strided_kernels_match_the_strided_reference() {
        use crate::conv::reference::conv2d_ref_strided;
        // Above the PAR_MIN_MACS cutoff so the pooled dense pass of the
        // subsample adapter genuinely runs.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let mut rng = Rng::new(45);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let pool = ThreadPool::new(3);
        for stride in [2usize, 3] {
            let want = conv2d_ref_strided(&input, &weights, shape, stride);
            let kernels: Vec<Box<dyn ConvKernel>> = vec![
                Box::new(BaselineKernel::with_stride(shape, weights.to_vec(), stride)),
                Box::new(HiKonvKernel::with_stride(
                    Conv2dHiKonv::new(spec, &weights).unwrap(),
                    false,
                    None,
                    stride,
                )),
                Box::new(HiKonvKernel::with_stride(
                    Conv2dHiKonv::new(spec, &weights).unwrap(),
                    true,
                    None,
                    stride,
                )),
                Box::new(Im2RowKernel::new(
                    Im2RowConv::with_stride(spec, &weights, stride).unwrap(),
                    None,
                )),
            ];
            for kernel in kernels {
                assert_eq!(kernel.stride(), stride);
                assert_eq!(kernel.out_len(), want.len());
                assert_seq_eq(&kernel.conv(&input, None), &want).unwrap();
                assert_seq_eq(&kernel.conv(&input, Some(&pool)), &want).unwrap();
                // Reused scratch stays exact across frames.
                let mut scratch = kernel.new_scratch();
                let mut out = vec![31i64; kernel.out_len()];
                for _ in 0..2 {
                    kernel.conv_into(&input, &mut out, &mut scratch, Some(&pool));
                    assert_seq_eq(&out, &want).unwrap();
                }
            }
        }
    }

    #[test]
    fn tile_depth_override_stays_exact() {
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        let mut rng = Rng::new(44);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let want = conv2d_ref(&input, &weights, shape);
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let pool = ThreadPool::new(4);
        // Degenerate overrides included: 0 and over-co clamp inside the
        // tiling entry points.
        for tile_co in [0usize, 1, 3, 5, 12, 100] {
            let k1 = HiKonvKernel::new(
                Conv2dHiKonv::new(spec, &weights).unwrap(),
                true,
                Some(tile_co),
            );
            assert_seq_eq(&k1.conv(&input, Some(&pool)), &want).unwrap();
            let k2 = Im2RowKernel::new(Im2RowConv::new(spec, &weights).unwrap(), Some(tile_co));
            assert_seq_eq(&k2.conv(&input, Some(&pool)), &want).unwrap();
        }
    }
}
