//! Unified engine configuration, kernels and planning.
//!
//! Three layers make up the engine API:
//!
//! * [`EngineConfig`] — one typed builder (and one textual grammar) for
//!   everything that used to be the closed `EngineKind` enum plus ad-hoc
//!   tuples: kernel choice, multiplier, thread budget, bitwidths and
//!   signedness, tile/block overrides, lane-bound reporting width.
//! * [`ConvKernel`] + [`KernelRegistry`] — the object-safe capability
//!   trait every backend implements and the registry it plugs into; the
//!   runner, coordinator and CLI resolve kernels by name instead of
//!   hard-wiring engine types through every layer of the stack.
//! * [`EnginePlan`] — the theory-driven per-layer planner:
//!   `EngineConfig::auto()` scores every registered kernel per layer
//!   with the paper's design-point solver and picks the predicted-best
//!   one, producing an inspectable plan (`hikonv plan`).
//!
//! This module also hosts the free-function tiling entry points the
//! kernels (and benches) share: [`conv2d_tiled`] / [`im2row_tiled`] and
//! their write-into twins, which shard output channels across an
//! [`exec::ThreadPool`](crate::exec::ThreadPool).

mod config;
mod kernel;
mod planner;
mod registry;

pub use config::{EngineConfig, KernelChoice};
pub use kernel::{
    BaselineKernel, ConvKernel, HiKonvKernel, Im2RowKernel, KernelScratch, PackedWeights,
};
pub use planner::{EnginePlan, LayerPlan};
pub use registry::{KernelFactory, KernelRegistry};

use crate::conv::conv2d::{Conv2dHiKonv, PackedInput};
use crate::conv::gemm::PackedLhs;
use crate::conv::im2row::Im2RowConv;
use crate::exec::ThreadPool;

/// Output-channel tile depth for a layer of `co` channels on a pool of
/// `threads` workers: ~4 tiles per worker for load balance, never below
/// one channel per tile. The worker count is clamped to `co` first, so a
/// degenerate `threads > co` pool yields at most `co` one-channel tiles
/// (never empty ones) instead of over-splitting.
pub fn tile_co_for(co: usize, threads: usize) -> usize {
    let workers = threads.clamp(1, co.max(1));
    co.div_ceil((workers * 4).min(co.max(1))).max(1)
}

/// Below this many MACs a layer runs serially even on a multi-thread
/// pool: the scoped worker spawn/join (~tens of µs per call) amortizes
/// poorly against sub-100µs tile compute, so tiny layers would get
/// *slower* tiled (the serve path calls this once per layer per frame).
/// Public so callers holding their own scratch (the fused runner's
/// arena) can apply the same cutoff and drive the allocation-free
/// serial path directly; the planner's cost model charges pooled kernels
/// the same spawn cost.
pub const PAR_MIN_MACS: u64 = 100_000;

/// Run one HiKonv conv2d layer tiled over output channels on `pool`:
/// pack the input once, then shard `[co_start, co_end)` ranges across the
/// workers. Bit-exact vs `eng.conv` (and `conv2d_ref`) for any thread
/// count — tiles are disjoint output regions addressed by index, and the
/// small-layer serial cutoff changes scheduling only, never values.
pub fn conv2d_tiled(eng: &Conv2dHiKonv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.shape();
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let packed = eng.pack_input(input);
    let mut out = vec![0i64; sh.output_len()];
    conv2d_tiled_into(eng, pool, &packed, &mut out);
    out
}

/// [`conv2d_tiled`] on an already-packed input, writing into a
/// caller-provided buffer (`co·ho·wo`, overwritten) — the write-into
/// tiling contract: the fused pipeline packs into its arena once and
/// shards from there. Applies the same small-layer serial cutoff, so it
/// stays bit-identical to [`conv2d_tiled`] and `eng.conv`.
pub fn conv2d_tiled_into(
    eng: &Conv2dHiKonv,
    pool: &ThreadPool,
    packed: &PackedInput,
    out: &mut [i64],
) {
    conv2d_tiled_into_depth(
        eng,
        pool,
        packed,
        tile_co_for(eng.shape().co, pool.threads()),
        out,
    );
}

/// [`conv2d_tiled_into`] with an explicit output-channel tile depth
/// (`EngineConfig::tile_co` override; clamped to `[1, co]`).
pub fn conv2d_tiled_into_depth(
    eng: &Conv2dHiKonv,
    pool: &ThreadPool,
    packed: &PackedInput,
    tile_co: usize,
    out: &mut [i64],
) {
    let sh = eng.shape();
    assert_eq!(out.len(), sh.output_len(), "output length mismatch");
    // `conv_co_range` accumulates with `+=`: zero the (reused) buffer.
    out.iter_mut().for_each(|v| *v = 0);
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        eng.conv_co_range(packed, 0, sh.co, out);
        return;
    }
    let (ho, wo) = (sh.ho(), sh.wo());
    let tile_co = tile_co.clamp(1, sh.co);
    pool.par_chunks_mut(out, tile_co * ho * wo, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_co_range(packed, co_start, co_end, tile);
    });
}

/// Run one im2row/GEMM layer tiled over output channels on `pool`: pack
/// the pixel rows once (streaming im2row — weights were packed at engine
/// construction), then shard `[co_start, co_end)` column ranges across
/// the workers; each tile is a contiguous co-major output region, so no
/// transpose ever runs. Bit-exact vs `eng.conv` (and `conv2d_ref`) for
/// any thread count — the same index-addressed determinism contract as
/// [`conv2d_tiled`].
pub fn im2row_tiled(eng: &Im2RowConv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.spec().shape;
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let pixels = eng.pack_pixels(input);
    let mut out = vec![0i64; eng.out_len()];
    im2row_tiled_into(eng, pool, &pixels, &mut out);
    out
}

/// [`im2row_tiled`] on already-packed pixel rows, writing into a
/// caller-provided buffer (`co·ho·wo` co-major, overwritten) — the
/// write-into tiling contract for the im2row/GEMM lowering. Applies the
/// same small-layer serial cutoff, so it stays bit-identical to
/// [`im2row_tiled`] and `eng.conv`.
pub fn im2row_tiled_into(eng: &Im2RowConv, pool: &ThreadPool, pixels: &PackedLhs, out: &mut [i64]) {
    im2row_tiled_into_depth(
        eng,
        pool,
        pixels,
        tile_co_for(eng.spec().shape.co, pool.threads()),
        out,
    );
}

/// [`im2row_tiled_into`] with an explicit output-channel tile depth
/// (`EngineConfig::tile_co` override; clamped to `[1, co]`).
pub fn im2row_tiled_into_depth(
    eng: &Im2RowConv,
    pool: &ThreadPool,
    pixels: &PackedLhs,
    tile_co: usize,
    out: &mut [i64],
) {
    let sh = eng.spec().shape;
    assert_eq!(out.len(), eng.out_len(), "output length mismatch");
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        eng.conv_cols(pixels, 0, sh.co, out);
        return;
    }
    let rows = eng.rows();
    let tile_co = tile_co.clamp(1, sh.co);
    pool.par_chunks_mut(out, tile_co * rows, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_cols(pixels, co_start, co_end, tile);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv2d::Conv2dSpec;
    use crate::conv::reference::{conv2d_ref, ConvShape};
    use crate::testing::assert_seq_eq;
    use crate::theory::{Multiplier, Signedness};
    use crate::util::rng::Rng;

    #[test]
    fn tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff, so the
        // parallel path is what's being tested.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(43);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let serial = conv2d_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        for threads in [2usize, 4, 8, 32] {
            let par = conv2d_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn im2row_tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(44);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        let serial = im2row_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        assert_seq_eq(&serial, &conv2d_ref(&input, &weights, shape)).unwrap();
        for threads in [2usize, 4, 8, 32] {
            let par = im2row_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn tiled_into_matches_tiled_above_and_below_cutoff() {
        // One shape above the serial cutoff, one below: both must agree
        // with the allocating entry points bit-for-bit.
        for (shape, seed) in [
            (
                ConvShape {
                    ci: 6,
                    co: 12,
                    hi: 10,
                    wi: 34,
                    k: 3,
                },
                46u64,
            ),
            (
                ConvShape {
                    ci: 2,
                    co: 3,
                    hi: 6,
                    wi: 8,
                    k: 3,
                },
                47,
            ),
        ] {
            let mut rng = Rng::new(seed);
            let weights = rng.quant_signed_vec(4, shape.weight_len());
            let input = rng.quant_unsigned_vec(4, shape.input_len());
            let spec = Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: 4,
                q: 4,
                signedness: Signedness::UnsignedBySigned,
            };
            let pool = ThreadPool::new(4);
            let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
            let mut out = vec![7i64; shape.output_len()];
            conv2d_tiled_into(&eng, &pool, &eng.pack_input(&input), &mut out);
            assert_seq_eq(&out, &conv2d_tiled(&eng, &pool, &input)).unwrap();
            let im = Im2RowConv::new(spec, &weights).unwrap();
            let mut out2 = vec![7i64; shape.output_len()];
            im2row_tiled_into(&im, &pool, &im.pack_pixels(&input), &mut out2);
            assert_seq_eq(&out2, &im2row_tiled(&im, &pool, &input)).unwrap();
            assert_seq_eq(&out, &out2).unwrap();
        }
    }

    #[test]
    fn explicit_tile_depths_compose_exactly() {
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(48);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let want = conv2d_ref(&input, &weights, shape);
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let pool = ThreadPool::new(4);
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let im = Im2RowConv::new(spec, &weights).unwrap();
        let packed = eng.pack_input(&input);
        let pixels = im.pack_pixels(&input);
        // Degenerate depths (0, over-co) are clamped, never panic.
        for depth in [0usize, 1, 5, 12, 64] {
            let mut out = vec![9i64; shape.output_len()];
            conv2d_tiled_into_depth(&eng, &pool, &packed, depth, &mut out);
            assert_seq_eq(&out, &want).unwrap();
            im2row_tiled_into_depth(&im, &pool, &pixels, depth, &mut out);
            assert_seq_eq(&out, &want).unwrap();
        }
    }

    #[test]
    fn tile_depth_heuristic_bounds() {
        assert_eq!(tile_co_for(64, 1), 16);
        assert_eq!(tile_co_for(64, 4), 4);
        assert_eq!(tile_co_for(3, 8), 1);
        assert_eq!(tile_co_for(1, 16), 1);
        // Degenerate inputs clamp instead of panicking or over-splitting:
        // never more than `co` tiles, never an empty tile.
        assert_eq!(tile_co_for(0, 4), 1);
        assert_eq!(tile_co_for(5, 0), 2);
        for co in [1usize, 3, 7, 64] {
            for threads in [1usize, 2, 16, 100] {
                let depth = tile_co_for(co, threads);
                assert!(depth >= 1);
                assert!(co.div_ceil(depth) <= co, "co={co} threads={threads}");
            }
        }
    }
}
