//! Pluggable convolution-engine abstraction used by benches and the
//! coordinator: the same layer can run on the baseline loop nest, the
//! HiKonv packed engine, or (whole-model) a PJRT-compiled artifact.

use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use crate::conv::reference::{conv2d_ref, ConvShape};
use crate::theory::{Multiplier, Signedness};

/// A layer-level convolution engine with bound weights.
pub trait ConvEngine: Send {
    /// Engine name for reports.
    fn name(&self) -> &str;
    /// Execute the layer on `[ci][h][w]` activations.
    fn conv(&self, input: &[i64]) -> Vec<i64>;
    /// The layer shape this engine was built for.
    fn shape(&self) -> ConvShape;
}

/// Baseline 6-loop engine (Eq. 17).
pub struct BaselineEngine {
    shape: ConvShape,
    weights: Vec<i64>,
}

impl BaselineEngine {
    pub fn new(shape: ConvShape, weights: Vec<i64>) -> BaselineEngine {
        assert_eq!(weights.len(), shape.weight_len());
        BaselineEngine { shape, weights }
    }
}

impl ConvEngine for BaselineEngine {
    fn name(&self) -> &str {
        "baseline"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        conv2d_ref(input, &self.weights, self.shape)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// HiKonv packed engine (Thms. 1–3).
pub struct HiKonvEngine {
    inner: Conv2dHiKonv,
    shape: ConvShape,
}

impl HiKonvEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
    ) -> Result<HiKonvEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(HiKonvEngine {
            inner: Conv2dHiKonv::new(spec, &weights)?,
            shape,
        })
    }
}

impl ConvEngine for HiKonvEngine {
    fn name(&self) -> &str {
        "hikonv"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        self.inner.conv(input)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_seq_eq;
    use crate::util::rng::Rng;

    #[test]
    fn engines_agree_via_trait_objects() {
        let shape = ConvShape {
            ci: 4,
            co: 3,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(41);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(
                    shape,
                    weights,
                    Multiplier::CPU32,
                    4,
                    4,
                    Signedness::UnsignedBySigned,
                )
                .unwrap(),
            ),
        ];
        let outputs: Vec<Vec<i64>> = engines.iter().map(|e| e.conv(&input)).collect();
        assert_seq_eq(&outputs[0], &outputs[1]).unwrap();
        assert_eq!(engines[0].name(), "baseline");
        assert_eq!(engines[1].shape(), shape);
    }
}
