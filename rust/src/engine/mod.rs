//! Pluggable convolution-engine abstraction used by benches and the
//! coordinator: the same layer can run on the baseline loop nest, the
//! HiKonv packed engine, the parallel tiled engine (output channels
//! sharded across an [`exec::ThreadPool`](crate::exec::ThreadPool)), the
//! im2row/pre-packed-GEMM lowering (also pool-tiled, via
//! [`im2row_tiled`]), or (whole-model) a PJRT-compiled artifact.

use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use crate::conv::im2row::Im2RowConv;
use crate::conv::reference::{conv2d_ref, ConvShape};
use crate::exec::ThreadPool;
use crate::theory::{Multiplier, Signedness};
use std::sync::Arc;

/// A layer-level convolution engine with bound weights.
pub trait ConvEngine: Send {
    /// Engine name for reports.
    fn name(&self) -> &str;
    /// Execute the layer on `[ci][h][w]` activations.
    fn conv(&self, input: &[i64]) -> Vec<i64>;
    /// The layer shape this engine was built for.
    fn shape(&self) -> ConvShape;
}

/// Baseline 6-loop engine (Eq. 17).
pub struct BaselineEngine {
    shape: ConvShape,
    weights: Vec<i64>,
}

impl BaselineEngine {
    pub fn new(shape: ConvShape, weights: Vec<i64>) -> BaselineEngine {
        assert_eq!(weights.len(), shape.weight_len());
        BaselineEngine { shape, weights }
    }
}

impl ConvEngine for BaselineEngine {
    fn name(&self) -> &str {
        "baseline"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        conv2d_ref(input, &self.weights, self.shape)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// HiKonv packed engine (Thms. 1–3).
pub struct HiKonvEngine {
    inner: Conv2dHiKonv,
    shape: ConvShape,
}

impl HiKonvEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
    ) -> Result<HiKonvEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(HiKonvEngine {
            inner: Conv2dHiKonv::new(spec, &weights)?,
            shape,
        })
    }
}

impl ConvEngine for HiKonvEngine {
    fn name(&self) -> &str {
        "hikonv"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        self.inner.conv(input)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// Output-channel tile depth for a layer of `co` channels on a pool of
/// `threads` workers: ~4 tiles per worker for load balance, never below
/// one channel per tile.
pub fn tile_co_for(co: usize, threads: usize) -> usize {
    co.div_ceil((threads * 4).max(1)).max(1)
}

/// Below this many MACs a layer runs serially even on a multi-thread
/// pool: the scoped worker spawn/join (~tens of µs per call) amortizes
/// poorly against sub-100µs tile compute, so tiny layers would get
/// *slower* tiled (the serve path calls this once per layer per frame).
const PAR_MIN_MACS: u64 = 100_000;

/// Run one HiKonv conv2d layer tiled over output channels on `pool`:
/// pack the input once, then shard `[co_start, co_end)` ranges across the
/// workers. Bit-exact vs `eng.conv` (and `conv2d_ref`) for any thread
/// count — tiles are disjoint output regions addressed by index, and the
/// small-layer serial cutoff changes scheduling only, never values.
pub fn conv2d_tiled(eng: &Conv2dHiKonv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.shape();
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let packed = eng.pack_input(input);
    let (ho, wo) = (sh.ho(), sh.wo());
    let tile_co = tile_co_for(sh.co, pool.threads());
    let mut out = vec![0i64; sh.output_len()];
    pool.par_chunks_mut(&mut out, tile_co * ho * wo, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_co_range(&packed, co_start, co_end, tile);
    });
    out
}

/// Parallel tiled HiKonv engine: Thm.-3 packed arithmetic with output
/// channels sharded across a thread pool (the multi-core extension of the
/// paper's CPU result).
pub struct ParallelEngine {
    inner: Conv2dHiKonv,
    shape: ConvShape,
    pool: Arc<ThreadPool>,
}

impl ParallelEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        pool: Arc<ThreadPool>,
    ) -> Result<ParallelEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(ParallelEngine {
            inner: Conv2dHiKonv::new(spec, &weights)?,
            shape,
            pool,
        })
    }

    /// Convenience: build with a private pool of `threads` workers
    /// (0 = auto-size from the machine / `HIKONV_THREADS`).
    pub fn with_threads(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        threads: usize,
    ) -> Result<ParallelEngine, String> {
        Self::new(
            shape,
            weights,
            mult,
            p,
            q,
            signedness,
            Arc::new(ThreadPool::auto_sized(threads)),
        )
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl ConvEngine for ParallelEngine {
    fn name(&self) -> &str {
        "hikonv-tiled"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        conv2d_tiled(&self.inner, &self.pool, input)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// Run one im2row/GEMM layer tiled over output channels on `pool`: pack
/// the pixel rows once (streaming im2row — weights were packed at engine
/// construction), then shard `[co_start, co_end)` column ranges across
/// the workers; each tile is a contiguous co-major output region, so no
/// transpose ever runs. Bit-exact vs `eng.conv` (and `conv2d_ref`) for
/// any thread count — the same index-addressed determinism contract as
/// [`conv2d_tiled`].
pub fn im2row_tiled(eng: &Im2RowConv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.spec().shape;
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let pixels = eng.pack_pixels(input);
    let rows = sh.ho() * sh.wo();
    let tile_co = tile_co_for(sh.co, pool.threads());
    let mut out = vec![0i64; sh.output_len()];
    pool.par_chunks_mut(&mut out, tile_co * rows, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_cols(&pixels, co_start, co_end, tile);
    });
    out
}

/// im2row/GEMM lowering engine: weights pre-packed at construction,
/// activations packed once per inference, output channels sharded across
/// a thread pool (the FC-shaped counterpart of [`ParallelEngine`]).
pub struct Im2RowEngine {
    inner: Im2RowConv,
    shape: ConvShape,
    pool: Arc<ThreadPool>,
}

impl Im2RowEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        pool: Arc<ThreadPool>,
    ) -> Result<Im2RowEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(Im2RowEngine {
            inner: Im2RowConv::new(spec, &weights)?,
            shape,
            pool,
        })
    }

    /// Convenience: build with a private pool of `threads` workers
    /// (0 = auto-size from the machine / `HIKONV_THREADS`).
    pub fn with_threads(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        threads: usize,
    ) -> Result<Im2RowEngine, String> {
        Self::new(
            shape,
            weights,
            mult,
            p,
            q,
            signedness,
            Arc::new(ThreadPool::auto_sized(threads)),
        )
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl ConvEngine for Im2RowEngine {
    fn name(&self) -> &str {
        "im2row"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        im2row_tiled(&self.inner, &self.pool, input)
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_seq_eq;
    use crate::util::rng::Rng;

    #[test]
    fn engines_agree_via_trait_objects() {
        let shape = ConvShape {
            ci: 4,
            co: 3,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(41);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(
                    shape,
                    weights,
                    Multiplier::CPU32,
                    4,
                    4,
                    Signedness::UnsignedBySigned,
                )
                .unwrap(),
            ),
        ];
        let outputs: Vec<Vec<i64>> = engines.iter().map(|e| e.conv(&input)).collect();
        assert_seq_eq(&outputs[0], &outputs[1]).unwrap();
        assert_eq!(engines[0].name(), "baseline");
        assert_eq!(engines[1].shape(), shape);
    }

    #[test]
    fn all_engines_agree_including_tiled_and_im2row() {
        let shape = ConvShape {
            ci: 5,
            co: 7,
            hi: 8,
            wi: 13,
            k: 3,
        };
        let mut rng = Rng::new(42);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let sgn = Signedness::UnsignedBySigned;
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(shape, weights.clone(), Multiplier::CPU32, 4, 4, sgn).unwrap(),
            ),
            Box::new(
                ParallelEngine::with_threads(
                    shape,
                    weights.clone(),
                    Multiplier::CPU32,
                    4,
                    4,
                    sgn,
                    3,
                )
                .unwrap(),
            ),
            Box::new(
                Im2RowEngine::with_threads(shape, weights, Multiplier::CPU32, 4, 4, sgn, 2)
                    .unwrap(),
            ),
        ];
        let reference = engines[0].conv(&input);
        for e in &engines[1..] {
            assert_seq_eq(&e.conv(&input), &reference).unwrap();
        }
        assert_eq!(engines[2].name(), "hikonv-tiled");
        assert_eq!(engines[3].name(), "im2row");
    }

    #[test]
    fn tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff, so the
        // parallel path is what's being tested.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(43);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let serial = conv2d_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        for threads in [2usize, 4, 8] {
            let par = conv2d_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn im2row_tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(44);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        let serial = im2row_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        assert_seq_eq(&serial, &conv2d_ref(&input, &weights, shape)).unwrap();
        for threads in [2usize, 4, 8] {
            let par = im2row_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn tile_depth_heuristic_bounds() {
        assert_eq!(tile_co_for(64, 1), 16);
        assert_eq!(tile_co_for(64, 4), 4);
        assert_eq!(tile_co_for(3, 8), 1);
        assert_eq!(tile_co_for(1, 16), 1);
    }
}
