//! Pluggable convolution-engine abstraction used by benches and the
//! coordinator: the same layer can run on the baseline loop nest, the
//! HiKonv packed engine, the parallel tiled engine (output channels
//! sharded across an [`exec::ThreadPool`](crate::exec::ThreadPool)), the
//! im2row/pre-packed-GEMM lowering (also pool-tiled, via
//! [`im2row_tiled`]), or (whole-model) a PJRT-compiled artifact.

use crate::conv::conv2d::{Conv2dHiKonv, Conv2dSpec, PackedInput};
use crate::conv::gemm::PackedLhs;
use crate::conv::im2row::Im2RowConv;
use crate::conv::reference::{conv2d_ref, conv2d_ref_into, ConvShape};
use crate::exec::ThreadPool;
use crate::theory::{Multiplier, Signedness};
use std::sync::Arc;

/// A layer-level convolution engine with bound weights.
pub trait ConvEngine: Send {
    /// Engine name for reports.
    fn name(&self) -> &str;
    /// Execute the layer on `[ci][h][w]` activations.
    fn conv(&self, input: &[i64]) -> Vec<i64>;
    /// Execute the layer into a caller-provided buffer (`co·ho·wo`,
    /// overwritten) — the write-into contract the fused model pipeline
    /// drives so layer outputs land in arena buffers instead of fresh
    /// allocations. Engines override the default (which copies through
    /// [`conv`](Self::conv)) with a genuinely allocation-lean path.
    fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        out.copy_from_slice(&self.conv(input));
    }
    /// The layer shape this engine was built for.
    fn shape(&self) -> ConvShape;
}

/// Baseline 6-loop engine (Eq. 17).
pub struct BaselineEngine {
    shape: ConvShape,
    weights: Vec<i64>,
}

impl BaselineEngine {
    pub fn new(shape: ConvShape, weights: Vec<i64>) -> BaselineEngine {
        assert_eq!(weights.len(), shape.weight_len());
        BaselineEngine { shape, weights }
    }
}

impl ConvEngine for BaselineEngine {
    fn name(&self) -> &str {
        "baseline"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        conv2d_ref(input, &self.weights, self.shape)
    }
    fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        conv2d_ref_into(input, &self.weights, self.shape, out);
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// HiKonv packed engine (Thms. 1–3).
pub struct HiKonvEngine {
    inner: Conv2dHiKonv,
    shape: ConvShape,
}

impl HiKonvEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
    ) -> Result<HiKonvEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(HiKonvEngine {
            inner: Conv2dHiKonv::new(spec, &weights)?,
            shape,
        })
    }
}

impl ConvEngine for HiKonvEngine {
    fn name(&self) -> &str {
        "hikonv"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        self.inner.conv(input)
    }
    fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        self.inner.conv_into(input, out);
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// Output-channel tile depth for a layer of `co` channels on a pool of
/// `threads` workers: ~4 tiles per worker for load balance, never below
/// one channel per tile.
pub fn tile_co_for(co: usize, threads: usize) -> usize {
    co.div_ceil((threads * 4).max(1)).max(1)
}

/// Below this many MACs a layer runs serially even on a multi-thread
/// pool: the scoped worker spawn/join (~tens of µs per call) amortizes
/// poorly against sub-100µs tile compute, so tiny layers would get
/// *slower* tiled (the serve path calls this once per layer per frame).
/// Public so callers holding their own scratch (the fused runner's
/// arena) can apply the same cutoff and drive the allocation-free
/// serial path directly.
pub const PAR_MIN_MACS: u64 = 100_000;

/// Run one HiKonv conv2d layer tiled over output channels on `pool`:
/// pack the input once, then shard `[co_start, co_end)` ranges across the
/// workers. Bit-exact vs `eng.conv` (and `conv2d_ref`) for any thread
/// count — tiles are disjoint output regions addressed by index, and the
/// small-layer serial cutoff changes scheduling only, never values.
pub fn conv2d_tiled(eng: &Conv2dHiKonv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.shape();
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let packed = eng.pack_input(input);
    let mut out = vec![0i64; sh.output_len()];
    conv2d_tiled_into(eng, pool, &packed, &mut out);
    out
}

/// [`conv2d_tiled`] on an already-packed input, writing into a
/// caller-provided buffer (`co·ho·wo`, overwritten) — the write-into
/// tiling contract: the fused pipeline packs into its arena once and
/// shards from there. Applies the same small-layer serial cutoff, so it
/// stays bit-identical to [`conv2d_tiled`] and `eng.conv`.
pub fn conv2d_tiled_into(
    eng: &Conv2dHiKonv,
    pool: &ThreadPool,
    packed: &PackedInput,
    out: &mut [i64],
) {
    let sh = eng.shape();
    assert_eq!(out.len(), sh.output_len(), "output length mismatch");
    // `conv_co_range` accumulates with `+=`: zero the (reused) buffer.
    out.iter_mut().for_each(|v| *v = 0);
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        eng.conv_co_range(packed, 0, sh.co, out);
        return;
    }
    let (ho, wo) = (sh.ho(), sh.wo());
    let tile_co = tile_co_for(sh.co, pool.threads());
    pool.par_chunks_mut(out, tile_co * ho * wo, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_co_range(packed, co_start, co_end, tile);
    });
}

/// Parallel tiled HiKonv engine: Thm.-3 packed arithmetic with output
/// channels sharded across a thread pool (the multi-core extension of the
/// paper's CPU result).
pub struct ParallelEngine {
    inner: Conv2dHiKonv,
    shape: ConvShape,
    pool: Arc<ThreadPool>,
}

impl ParallelEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        pool: Arc<ThreadPool>,
    ) -> Result<ParallelEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(ParallelEngine {
            inner: Conv2dHiKonv::new(spec, &weights)?,
            shape,
            pool,
        })
    }

    /// Convenience: build with a private pool of `threads` workers
    /// (0 = auto-size from the machine / `HIKONV_THREADS`).
    pub fn with_threads(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        threads: usize,
    ) -> Result<ParallelEngine, String> {
        Self::new(
            shape,
            weights,
            mult,
            p,
            q,
            signedness,
            Arc::new(ThreadPool::auto_sized(threads)),
        )
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl ConvEngine for ParallelEngine {
    fn name(&self) -> &str {
        "hikonv-tiled"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        conv2d_tiled(&self.inner, &self.pool, input)
    }
    fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        let packed = self.inner.pack_input(input);
        conv2d_tiled_into(&self.inner, &self.pool, &packed, out);
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

/// Run one im2row/GEMM layer tiled over output channels on `pool`: pack
/// the pixel rows once (streaming im2row — weights were packed at engine
/// construction), then shard `[co_start, co_end)` column ranges across
/// the workers; each tile is a contiguous co-major output region, so no
/// transpose ever runs. Bit-exact vs `eng.conv` (and `conv2d_ref`) for
/// any thread count — the same index-addressed determinism contract as
/// [`conv2d_tiled`].
pub fn im2row_tiled(eng: &Im2RowConv, pool: &ThreadPool, input: &[i64]) -> Vec<i64> {
    let sh = eng.spec().shape;
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        return eng.conv(input);
    }
    let pixels = eng.pack_pixels(input);
    let mut out = vec![0i64; sh.output_len()];
    im2row_tiled_into(eng, pool, &pixels, &mut out);
    out
}

/// [`im2row_tiled`] on already-packed pixel rows, writing into a
/// caller-provided buffer (`co·ho·wo` co-major, overwritten) — the
/// write-into tiling contract for the im2row/GEMM lowering. Applies the
/// same small-layer serial cutoff, so it stays bit-identical to
/// [`im2row_tiled`] and `eng.conv`.
pub fn im2row_tiled_into(eng: &Im2RowConv, pool: &ThreadPool, pixels: &PackedLhs, out: &mut [i64]) {
    let sh = eng.spec().shape;
    assert_eq!(out.len(), sh.output_len(), "output length mismatch");
    if pool.threads() == 1 || sh.macs() < PAR_MIN_MACS {
        eng.conv_cols(pixels, 0, sh.co, out);
        return;
    }
    let rows = sh.ho() * sh.wo();
    let tile_co = tile_co_for(sh.co, pool.threads());
    pool.par_chunks_mut(out, tile_co * rows, |tile_idx, tile| {
        let co_start = tile_idx * tile_co;
        let co_end = (co_start + tile_co).min(sh.co);
        eng.conv_cols(pixels, co_start, co_end, tile);
    });
}

/// im2row/GEMM lowering engine: weights pre-packed at construction,
/// activations packed once per inference, output channels sharded across
/// a thread pool (the FC-shaped counterpart of [`ParallelEngine`]).
pub struct Im2RowEngine {
    inner: Im2RowConv,
    shape: ConvShape,
    pool: Arc<ThreadPool>,
}

impl Im2RowEngine {
    pub fn new(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        pool: Arc<ThreadPool>,
    ) -> Result<Im2RowEngine, String> {
        let spec = Conv2dSpec {
            shape,
            mult,
            p,
            q,
            signedness,
        };
        Ok(Im2RowEngine {
            inner: Im2RowConv::new(spec, &weights)?,
            shape,
            pool,
        })
    }

    /// Convenience: build with a private pool of `threads` workers
    /// (0 = auto-size from the machine / `HIKONV_THREADS`).
    pub fn with_threads(
        shape: ConvShape,
        weights: Vec<i64>,
        mult: Multiplier,
        p: u32,
        q: u32,
        signedness: Signedness,
        threads: usize,
    ) -> Result<Im2RowEngine, String> {
        Self::new(
            shape,
            weights,
            mult,
            p,
            q,
            signedness,
            Arc::new(ThreadPool::auto_sized(threads)),
        )
    }

    pub fn pool(&self) -> &Arc<ThreadPool> {
        &self.pool
    }
}

impl ConvEngine for Im2RowEngine {
    fn name(&self) -> &str {
        "im2row"
    }
    fn conv(&self, input: &[i64]) -> Vec<i64> {
        im2row_tiled(&self.inner, &self.pool, input)
    }
    fn conv_into(&self, input: &[i64], out: &mut [i64]) {
        let pixels = self.inner.pack_pixels(input);
        im2row_tiled_into(&self.inner, &self.pool, &pixels, out);
    }
    fn shape(&self) -> ConvShape {
        self.shape
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_seq_eq;
    use crate::util::rng::Rng;

    #[test]
    fn engines_agree_via_trait_objects() {
        let shape = ConvShape {
            ci: 4,
            co: 3,
            hi: 6,
            wi: 10,
            k: 3,
        };
        let mut rng = Rng::new(41);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(
                    shape,
                    weights,
                    Multiplier::CPU32,
                    4,
                    4,
                    Signedness::UnsignedBySigned,
                )
                .unwrap(),
            ),
        ];
        let outputs: Vec<Vec<i64>> = engines.iter().map(|e| e.conv(&input)).collect();
        assert_seq_eq(&outputs[0], &outputs[1]).unwrap();
        assert_eq!(engines[0].name(), "baseline");
        assert_eq!(engines[1].shape(), shape);
    }

    #[test]
    fn all_engines_agree_including_tiled_and_im2row() {
        let shape = ConvShape {
            ci: 5,
            co: 7,
            hi: 8,
            wi: 13,
            k: 3,
        };
        let mut rng = Rng::new(42);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let sgn = Signedness::UnsignedBySigned;
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(shape, weights.clone(), Multiplier::CPU32, 4, 4, sgn).unwrap(),
            ),
            Box::new(
                ParallelEngine::with_threads(
                    shape,
                    weights.clone(),
                    Multiplier::CPU32,
                    4,
                    4,
                    sgn,
                    3,
                )
                .unwrap(),
            ),
            Box::new(
                Im2RowEngine::with_threads(shape, weights, Multiplier::CPU32, 4, 4, sgn, 2)
                    .unwrap(),
            ),
        ];
        let reference = engines[0].conv(&input);
        for e in &engines[1..] {
            assert_seq_eq(&e.conv(&input), &reference).unwrap();
        }
        assert_eq!(engines[2].name(), "hikonv-tiled");
        assert_eq!(engines[3].name(), "im2row");
    }

    #[test]
    fn tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff, so the
        // parallel path is what's being tested.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(43);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
        let serial = conv2d_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        for threads in [2usize, 4, 8] {
            let par = conv2d_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn im2row_tiled_output_is_invariant_under_thread_count() {
        // Large enough to clear the PAR_MIN_MACS serial cutoff.
        let shape = ConvShape {
            ci: 6,
            co: 12,
            hi: 10,
            wi: 34,
            k: 3,
        };
        assert!(shape.macs() >= PAR_MIN_MACS);
        let mut rng = Rng::new(44);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let spec = Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        };
        let eng = Im2RowConv::new(spec, &weights).unwrap();
        let serial = im2row_tiled(&eng, &ThreadPool::new(1), &input);
        assert_seq_eq(&serial, &eng.conv(&input)).unwrap();
        assert_seq_eq(&serial, &conv2d_ref(&input, &weights, shape)).unwrap();
        for threads in [2usize, 4, 8] {
            let par = im2row_tiled(&eng, &ThreadPool::new(threads), &input);
            assert_seq_eq(&par, &serial).unwrap();
        }
    }

    #[test]
    fn conv_into_matches_conv_for_every_engine() {
        let shape = ConvShape {
            ci: 5,
            co: 6,
            hi: 8,
            wi: 12,
            k: 3,
        };
        let mut rng = Rng::new(45);
        let weights = rng.quant_signed_vec(4, shape.weight_len());
        let input = rng.quant_unsigned_vec(4, shape.input_len());
        let sgn = Signedness::UnsignedBySigned;
        let engines: Vec<Box<dyn ConvEngine>> = vec![
            Box::new(BaselineEngine::new(shape, weights.clone())),
            Box::new(
                HiKonvEngine::new(shape, weights.clone(), Multiplier::CPU32, 4, 4, sgn).unwrap(),
            ),
            Box::new(
                ParallelEngine::with_threads(
                    shape,
                    weights.clone(),
                    Multiplier::CPU32,
                    4,
                    4,
                    sgn,
                    3,
                )
                .unwrap(),
            ),
            Box::new(
                Im2RowEngine::with_threads(shape, weights.clone(), Multiplier::CPU32, 4, 4, sgn, 2)
                    .unwrap(),
            ),
        ];
        let want = conv2d_ref(&input, &weights, shape);
        let mut out = vec![123i64; shape.output_len()];
        for e in &engines {
            out.iter_mut().for_each(|v| *v = 123); // stale contents must be overwritten
            e.conv_into(&input, &mut out);
            assert_seq_eq(&out, &want).unwrap();
            assert_seq_eq(&e.conv(&input), &want).unwrap();
        }
    }

    #[test]
    fn tiled_into_matches_tiled_above_and_below_cutoff() {
        // One shape above the serial cutoff, one below: both must agree
        // with the allocating entry points bit-for-bit.
        for (shape, seed) in [
            (
                ConvShape {
                    ci: 6,
                    co: 12,
                    hi: 10,
                    wi: 34,
                    k: 3,
                },
                46u64,
            ),
            (
                ConvShape {
                    ci: 2,
                    co: 3,
                    hi: 6,
                    wi: 8,
                    k: 3,
                },
                47,
            ),
        ] {
            let mut rng = Rng::new(seed);
            let weights = rng.quant_signed_vec(4, shape.weight_len());
            let input = rng.quant_unsigned_vec(4, shape.input_len());
            let spec = Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: 4,
                q: 4,
                signedness: Signedness::UnsignedBySigned,
            };
            let pool = ThreadPool::new(4);
            let eng = Conv2dHiKonv::new(spec, &weights).unwrap();
            let mut out = vec![7i64; shape.output_len()];
            conv2d_tiled_into(&eng, &pool, &eng.pack_input(&input), &mut out);
            assert_seq_eq(&out, &conv2d_tiled(&eng, &pool, &input)).unwrap();
            let im = Im2RowConv::new(spec, &weights).unwrap();
            let mut out2 = vec![7i64; shape.output_len()];
            im2row_tiled_into(&im, &pool, &im.pack_pixels(&input), &mut out2);
            assert_seq_eq(&out2, &im2row_tiled(&im, &pool, &input)).unwrap();
            assert_seq_eq(&out, &out2).unwrap();
        }
    }

    #[test]
    fn tile_depth_heuristic_bounds() {
        assert_eq!(tile_co_for(64, 1), 16);
        assert_eq!(tile_co_for(64, 4), 4);
        assert_eq!(tile_co_for(3, 8), 1);
        assert_eq!(tile_co_for(1, 16), 1);
    }
}
