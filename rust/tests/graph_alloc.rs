//! Allocation accounting for the graph runner, with a counting global
//! allocator:
//!
//! * graph construction widens weights through **exactly one** shared
//!   scratch allocation (`QTensor::widen_into` instead of a per-kernel
//!   `to_i64()` `Vec`), and
//! * steady-state `infer_into` on a serial kernel plan performs **zero**
//!   heap allocations — for a graph exercising strided convs, an FC
//!   head and a residual add, not just the legacy UltraNet chain.
//!
//! The counter is global to the test binary, so the tests serialize on
//! a mutex instead of relying on test threading flags.

use hikonv::coordinator::{serve_registry, ModelRegistry, MultiServeConfig};
use hikonv::engine::EngineConfig;
use hikonv::models::{random_graph_weights, GraphRunner, GraphSpec};
use hikonv::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Allocation events of exactly [`WIDEN_BYTES`] bytes (the shared
/// weight-widening scratch size of `sized_graph`).
static WIDEN_SIZED: AtomicU64 = AtomicU64::new(0);
static GATE: Mutex<()> = Mutex::new(());

/// Every conv of `sized_graph` has this weight length, so the widening
/// scratch is exactly this many i64s — and no engine-internal buffer of
/// the graph shares the size (packed words, activations and
/// accumulators all differ).
const WIDEN_LEN: usize = 6 * 5 * 3 * 3; // co=6, ci=5, k=3
const WIDEN_BYTES: usize = WIDEN_LEN * std::mem::size_of::<i64>();

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        record(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        record(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

fn record(size: usize) {
    if COUNTING.load(Ordering::Relaxed) {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        if size == WIDEN_BYTES {
            WIDEN_SIZED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Three convs with identical `co·ci·k·k`, so a per-kernel `to_i64()`
/// regression would allocate the tell-tale size three times instead of
/// once. 5-channel 8×12 maps keep every other buffer size distinct from
/// [`WIDEN_BYTES`].
fn sized_graph() -> GraphSpec {
    GraphSpec::new("alloc-probe", (5, 8, 12), 4)
        .conv("c1", 6, 3, 1, 1, 4)
        .requant(4)
        .conv("c2", 5, 3, 1, 1, 4) // note: ci=6 -> co=5 keeps the product equal
        .requant(4)
        .conv("c3", 6, 3, 1, 1, 4)
}

/// Strided + FC + residual graph for the zero-alloc steady-state check.
fn feature_graph() -> GraphSpec {
    let g = GraphSpec::new("features", (3, 12, 12), 4)
        .conv("down", 6, 3, 2, 1, 4) // stride 2 -> 6 x 6 x 6
        .requant(4);
    let skip = g.last_node();
    g.conv("b1", 6, 3, 1, 1, 4)
        .requant(4)
        .add(skip)
        .requant(4)
        .fc("head", 9, 4)
}

#[test]
fn graph_construction_widens_weights_exactly_once() {
    let _gate = GATE.lock().unwrap();
    let graph = sized_graph();
    {
        let info = graph.validate().unwrap();
        for u in &info.units {
            assert_eq!(u.weight_len(), WIDEN_LEN, "{}", u.name);
        }
    }
    let weights = random_graph_weights(&graph, 0x11D).unwrap();
    ALLOCS.store(0, Ordering::SeqCst);
    WIDEN_SIZED.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    let runner = GraphRunner::new(graph, weights, EngineConfig::named("hikonv")).unwrap();
    COUNTING.store(false, Ordering::SeqCst);
    assert_eq!(
        WIDEN_SIZED.load(Ordering::SeqCst),
        1,
        "weights must widen through one shared scratch, not per kernel"
    );
    drop(runner);
}

#[test]
fn multi_tenant_steady_state_runners_stay_zero_alloc_after_serving() {
    let _gate = GATE.lock().unwrap();
    // Two tenants serve a full supervised run (workers, queues and
    // reports all allocate freely), then each tenant's warmed runner —
    // the colored per-tenant arena the registry hands its workers —
    // must perform steady-state `infer_into` without touching the heap.
    let mut reg = ModelRegistry::new(EngineConfig::named("hikonv").with_threads(1));
    for name in ["a", "b"] {
        let g = feature_graph();
        let w = random_graph_weights(&g, 0x3AD).unwrap();
        reg.register_graph(name, g, w).unwrap();
    }
    let report = serve_registry(
        &mut reg,
        &MultiServeConfig {
            frames: 8,
            max_batch: 2,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(report.accounted());
    assert_eq!(report.total_completed(), 16);
    for name in ["a", "b"] {
        let runner = reg.tenant(name).unwrap().cell.get();
        let (c, h, w) = runner.graph().input;
        let mut rng = Rng::new(0x3AE);
        let warm_a = rng.quant_unsigned_vec(4, c * h * w);
        let warm_b = rng.quant_unsigned_vec(4, c * h * w);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let mut head = vec![0i64; runner.head_len()];
        runner.infer_into(&warm_a, &mut head);
        runner.infer_into(&warm_b, &mut head);
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        runner.infer_into(&frame, &mut head);
        COUNTING.store(false, Ordering::SeqCst);
        let allocs = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            allocs, 0,
            "tenant {name}: steady-state infer_into allocated {allocs} times after serving"
        );
    }
}

#[test]
fn steady_state_graph_infer_performs_zero_heap_allocations() {
    let _gate = GATE.lock().unwrap();
    for config in [
        EngineConfig::named("hikonv"),
        EngineConfig::named("im2row").with_threads(1),
    ] {
        let graph = feature_graph();
        let weights = random_graph_weights(&graph, 0x2AD).unwrap();
        let runner = GraphRunner::new(graph.clone(), weights, config.clone()).unwrap();
        let (c, h, w) = graph.input;
        let mut rng = Rng::new(0x2AE);
        let warm_a = rng.quant_unsigned_vec(4, c * h * w);
        let warm_b = rng.quant_unsigned_vec(4, c * h * w);
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let mut head = vec![0i64; runner.head_len()];
        // Warm the arena (first frames size packed buffers).
        runner.infer_into(&warm_a, &mut head);
        runner.infer_into(&warm_b, &mut head);
        ALLOCS.store(0, Ordering::SeqCst);
        COUNTING.store(true, Ordering::SeqCst);
        runner.infer_into(&frame, &mut head);
        COUNTING.store(false, Ordering::SeqCst);
        let allocs = ALLOCS.load(Ordering::SeqCst);
        assert_eq!(
            allocs, 0,
            "{config}: steady-state graph infer_into allocated {allocs} times"
        );
    }
}
