//! Arena-coloring acceptance suite.
//!
//! The dataflow pass (`hikonv::analysis`) colors step-program buffers
//! into a shared slot pool; these tests prove the three claims the
//! coloring ships under:
//!
//! 1. **Bit-exactness.** A runner executing on the colored arena agrees
//!    with the uncolored per-node walk (`infer_unfused`) and the
//!    strided-reference oracle for every zoo workload under every
//!    registered kernel and the auto planner.
//! 2. **It actually shrinks memory.** Colored arena bytes never exceed
//!    the one-buffer-per-node baseline, and strictly shrink on the
//!    `residual` and `mixed` workloads (the ones `BENCH_model.json`
//!    records).
//! 3. **Unsound layouts never execute.** A hand-edited artifact whose
//!    embedded layout folds concurrently-live buffers onto one slot is
//!    rejected at load with a stable `A-*` code — the checksum passes
//!    (the file is internally consistent), the dataflow proof does not.

use hikonv::artifact::Artifact;
use hikonv::engine::{EngineConfig, EnginePlan};
use hikonv::models::{random_graph_weights, zoo, GraphRunner, GraphSpec};
use hikonv::testing::assert_seq_eq;
use hikonv::util::rng::Rng;

/// Every zoo workload that the execution grid infers on (full-size
/// `ultranet` is covered by the planner-level grid below; running its
/// inference under the naive baseline kernel is debug-build-prohibitive).
fn inference_workloads() -> Vec<GraphSpec> {
    let mut v: Vec<GraphSpec> = ["ultranet-tiny", "strided", "fc-head", "residual", "mixed"]
        .iter()
        .map(|n| zoo::build(n).unwrap())
        .collect();
    v.push(zoo::combo());
    v
}

fn engine_matrix() -> Vec<EngineConfig> {
    vec![
        EngineConfig::named("baseline"),
        EngineConfig::named("hikonv"),
        EngineConfig::named("hikonv-tiled").with_threads(2),
        EngineConfig::named("im2row").with_threads(2),
        EngineConfig::auto().with_threads(2),
    ]
}

#[test]
fn colored_arenas_are_bit_exact_for_every_workload_and_kernel() {
    for graph in inference_workloads() {
        let weights = random_graph_weights(&graph, 0xC01).unwrap();
        let (c, h, w) = graph.input;
        let mut rng = Rng::new(0xC02 ^ graph.nodes.len() as u64);
        let frames: Vec<Vec<i64>> = (0..2)
            .map(|_| rng.quant_unsigned_vec(graph.input_bits, c * h * w))
            .collect();
        for config in engine_matrix() {
            let label = config.to_string();
            let r = GraphRunner::new(graph.clone(), weights.clone(), config)
                .unwrap_or_else(|e| panic!("{}/{label}: {e}", graph.name));
            assert!(
                r.arena_bytes() <= r.arena_baseline_bytes(),
                "{}/{label}: colored arena ({} B) exceeds the per-node baseline ({} B)",
                graph.name,
                r.arena_bytes(),
                r.arena_baseline_bytes()
            );
            for frame in &frames {
                let colored = r.infer(frame);
                // The per-node walk allocates one buffer per node — the
                // uncolored layout the colored arena must agree with.
                assert_seq_eq(&colored, &r.infer_unfused(frame))
                    .unwrap_or_else(|e| panic!("{}/{label} vs unfused: {e}", graph.name));
                assert_seq_eq(&colored, &r.infer_oracle(frame))
                    .unwrap_or_else(|e| panic!("{}/{label} vs oracle: {e}", graph.name));
            }
        }
    }
}

#[test]
fn every_zoo_workload_plans_a_sound_colored_layout() {
    // Planner-level grid (no weights, no inference): all six zoo names,
    // including full-size ultranet, get an arena summary whose colored
    // footprint never exceeds the baseline.
    for name in zoo::NAMES {
        let graph = zoo::build(name).unwrap();
        let plan = EnginePlan::plan_graph(&graph, &EngineConfig::auto().with_threads(1))
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let arena = plan
            .arena
            .unwrap_or_else(|| panic!("{name}: plan_graph must attach an arena summary"));
        assert!(
            arena.total_bytes <= arena.baseline_bytes,
            "{name}: colored {} B > baseline {} B",
            arena.total_bytes,
            arena.baseline_bytes
        );
        assert_eq!(arena.per_layer_bytes.len(), graph.validate().unwrap().units.len());
    }
}

#[test]
fn residual_and_mixed_workloads_strictly_shrink() {
    // The two workloads whose footprint reduction BENCH_model.json
    // records: coloring must beat one-buffer-per-node, not just tie it.
    for name in ["residual", "mixed"] {
        let graph = zoo::build(name).unwrap();
        let weights = random_graph_weights(&graph, 0xC03).unwrap();
        let r = GraphRunner::new(graph, weights, EngineConfig::named("hikonv")).unwrap();
        assert!(
            r.arena_bytes() < r.arena_baseline_bytes(),
            "{name}: colored arena ({} B) must be strictly below baseline ({} B)",
            r.arena_bytes(),
            r.arena_baseline_bytes()
        );
    }
}

#[test]
fn artifact_with_aliasing_layout_is_rejected_at_load_with_a_live() {
    // Hand-edit a residual artifact: fold every flat buffer onto slot 0.
    // The residual skip connection keeps its operand live across the
    // branch, so this layout would let a later in-place write clobber a
    // value the `Add` still reads. Round-trip through bytes so the file
    // is internally consistent — the checksum passes; the dataflow proof
    // is what rejects it, before any kernel is built or executed.
    let graph = zoo::build("residual").unwrap();
    let weights = random_graph_weights(&graph, 0xC04).unwrap();
    let mut art = Artifact::compile(graph, weights, EngineConfig::auto().with_threads(1)).unwrap();
    let folded_len = art
        .layout
        .flat_slot
        .iter()
        .flatten()
        .map(|&(_, len)| len)
        .max()
        .expect("residual materializes flat buffers");
    for entry in art.layout.flat_slot.iter_mut().flatten() {
        entry.0 = 0;
    }
    art.layout.flat_sizes = vec![folded_len];
    let reloaded = Artifact::from_bytes(&art.to_bytes()).expect("checksum is self-consistent");
    let err = reloaded.into_runner().unwrap_err();
    assert!(err.to_string().contains("A-LIVE"), "{err}");
}
