//! Planner correctness suite: `auto` plans are deterministic for a fixed
//! model + host signature, every planned layer is bit-exact vs the seed
//! `infer_unfused` oracle, forced single-backend plans match the legacy
//! `EngineKind` runners across thread counts, and unknown engine names
//! fail with the registered-name list plus a nearest-match suggestion.

use hikonv::engine::{EngineConfig, EnginePlan, KernelRegistry};
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::testing::assert_seq_eq;
use hikonv::theory::Multiplier;
use hikonv::util::rng::Rng;

#[test]
fn auto_plan_is_deterministic_for_a_fixed_model_and_host_signature() {
    let model = ultranet_tiny();
    for threads in [1usize, 2, 8] {
        let cfg = EngineConfig::auto().with_threads(threads);
        let first = EnginePlan::plan(&model, &cfg).unwrap();
        assert_eq!(first.layers.len(), model.layers.len());
        assert_eq!(first.threads, threads);
        for _ in 0..3 {
            let again = EnginePlan::plan(&model, &cfg).unwrap();
            assert_eq!(again.kernel_names(), first.kernel_names());
            assert_eq!(again.host(), first.host());
            assert_eq!(again.summary(), first.summary());
        }
    }
}

#[test]
fn auto_runner_is_bit_exact_vs_unfused_and_the_baseline_oracle() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 501);
    let oracle = CpuRunner::new(
        model.clone(),
        weights.clone(),
        EngineConfig::named("baseline"),
    )
    .unwrap();
    let (c, h, w) = model.input;
    for threads in [1usize, 2, 4] {
        let auto = CpuRunner::new(
            model.clone(),
            weights.clone(),
            EngineConfig::auto().with_threads(threads),
        )
        .unwrap();
        let mut rng = Rng::new(0xA070 + threads as u64);
        for _ in 0..2 {
            let frame = rng.quant_unsigned_vec(4, c * h * w);
            let got = auto.infer(&frame);
            assert_seq_eq(&got, &auto.infer_unfused(&frame)).unwrap();
            assert_seq_eq(&got, &oracle.infer_unfused(&frame)).unwrap();
        }
        // Batched execution (frame-level parallelism is retained for
        // `auto` plans even when every layer plans serial) stays
        // bit-identical to per-frame inference.
        let frames: Vec<Vec<i64>> =
            (0..4).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        for (f, b) in frames.iter().zip(&auto.infer_batch(&refs)) {
            assert_seq_eq(b, &auto.infer(f)).unwrap();
        }
    }
}

#[test]
fn forced_single_backend_plans_match_the_legacy_engine_kinds() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 502);
    let (c, h, w) = model.input;
    let m = Multiplier::CPU32;
    let frame = Rng::new(0xF0CA).quant_unsigned_vec(4, c * h * w);
    for threads in [1usize, 3] {
        let cases: Vec<(&str, EngineKind)> = vec![
            ("baseline", EngineKind::Baseline),
            ("hikonv", EngineKind::HiKonv(m)),
            ("hikonv-tiled", EngineKind::HiKonvTiled(m, threads)),
            ("im2row", EngineKind::Im2Row(m, threads)),
        ];
        for (spec, kind) in cases {
            let config: EngineConfig = spec.parse().unwrap();
            let new = CpuRunner::new(
                model.clone(),
                weights.clone(),
                config.with_threads(threads),
            )
            .unwrap();
            let old = CpuRunner::new(model.clone(), weights.clone(), kind).unwrap();
            assert_seq_eq(&new.infer(&frame), &old.infer(&frame)).unwrap();
            // The plan is the single forced kernel on every layer.
            assert!(
                new.plan().kernel_names().iter().all(|k| *k == spec),
                "{spec}: {:?}",
                new.plan().kernel_names()
            );
        }
    }
}

#[test]
fn unknown_engine_names_list_registered_names_and_suggest() {
    let err = KernelRegistry::builtin().resolve("hikov").unwrap_err();
    for name in ["baseline", "hikonv", "hikonv-tiled", "im2row"] {
        assert!(err.contains(name), "{err}");
    }
    assert!(err.contains("did you mean 'hikonv'"), "{err}");
    // The same error surfaces through runner construction from a config.
    let model = ultranet_tiny();
    let weights = random_weights(&model, 503);
    let err = CpuRunner::new(model, weights, EngineConfig::named("im2r0w")).unwrap_err();
    assert!(err.contains("did you mean 'im2row'"), "{err}");
}

#[test]
fn tiling_overrides_and_degenerate_thread_counts_stay_exact() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 504);
    let oracle = CpuRunner::new(
        model.clone(),
        weights.clone(),
        EngineConfig::named("baseline"),
    )
    .unwrap();
    let (c, h, w) = model.input;
    let frame = Rng::new(0xF0CB).quant_unsigned_vec(4, c * h * w);
    let want = oracle.infer(&frame);
    // Way more threads than any layer has output channels, plus explicit
    // tile/block overrides (including degenerate ones): still bit-exact.
    for spec in [
        "hikonv-tiled:threads=64",
        "hikonv-tiled:threads=64,tile-co=1",
        "hikonv-tiled:threads=3,tile-co=1000",
        "hikonv:block=2",
        "hikonv:block=1000",
        "im2row:threads=64,tile-co=1",
    ] {
        let config: EngineConfig = spec.parse().unwrap();
        let r = CpuRunner::new(model.clone(), weights.clone(), config).unwrap();
        assert_seq_eq(&r.infer(&frame), &want).unwrap();
        assert_seq_eq(&r.infer_unfused(&frame), &want).unwrap();
    }
}

#[test]
fn plan_table_reports_predicted_ops_per_mult_from_the_solver() {
    let model = ultranet_tiny();
    let plan = EnginePlan::plan(&model, &EngineConfig::auto().with_threads(2)).unwrap();
    let rendered = plan.render();
    for l in &model.layers {
        assert!(rendered.contains(&l.name), "missing {}: {rendered}", l.name);
    }
    for lp in &plan.layers {
        // Packed kernels at the 4-bit CPU32 point deliver multiple
        // equivalent ops per wide multiplication (paper Fig. 5b: 13).
        assert!(lp.ops_per_mult >= 2, "{lp:?}");
        assert!(lp.lane_bound >= lp.ops_per_mult, "{lp:?}");
    }
    let json = plan.to_json();
    assert!(json.get("layers").is_some());
    assert!(json.get("summary").is_some());
}
