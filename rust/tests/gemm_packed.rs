//! Properties of the pre-packed GEMM subsystem: `PackedGemm` matches the
//! `dot_ref`-based reference matmul over the full `(p, q) ∈ 1..=8`
//! bitwidth × signedness grid, tiled outputs are bit-identical for any
//! thread count, uneven row/column tiles compose exactly (mirroring
//! `tests/parallel_tiled.rs`), and the paper's CPU32 4-bit point selects
//! the `i64` fast lane.

use hikonv::conv::conv2d::Conv2dSpec;
use hikonv::conv::dot::{dot_ref, DotHiKonv};
use hikonv::conv::gemm::PackedGemm;
use hikonv::conv::im2row::Im2RowConv;
use hikonv::conv::reference::{conv2d_ref, ConvShape};
use hikonv::engine::im2row_tiled;
use hikonv::exec::ThreadPool;
use hikonv::testing::assert_seq_eq;
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::rng::Rng;

fn gen_vec(rng: &mut Rng, bits: u32, signed: bool, len: usize) -> Vec<i64> {
    if signed {
        rng.quant_signed_vec(bits, len)
    } else {
        rng.quant_unsigned_vec(bits, len)
    }
}

fn signed_operands(sgn: Signedness) -> (bool, bool) {
    match sgn {
        Signedness::Unsigned => (false, false),
        Signedness::Signed => (true, true),
        Signedness::UnsignedBySigned => (false, true),
    }
}

fn ref_matmul(a: &[i64], b_t: &[i64], m: usize, k: usize, n: usize) -> Vec<i64> {
    let mut out = vec![0i64; m * n];
    for row in 0..m {
        for col in 0..n {
            out[row * n + col] =
                dot_ref(&a[row * k..(row + 1) * k], &b_t[col * k..(col + 1) * k]);
        }
    }
    out
}

/// `PackedGemm` equals the scalar reference matmul for every bitwidth
/// pair and signedness on the 32×32 CPU multiplier, including inner
/// dimensions that don't divide the packing block (tail chunks).
#[test]
fn packed_gemm_matches_reference_over_full_bitwidth_grid() {
    let mut rng = Rng::new(0x6E88);
    let (m, n) = (5usize, 4usize);
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            for sgn in [
                Signedness::Unsigned,
                Signedness::Signed,
                Signedness::UnsignedBySigned,
            ] {
                for k in [1usize, 7, 37] {
                    let (sa, sb) = signed_operands(sgn);
                    let a = gen_vec(&mut rng, p, sa, m * k);
                    let bt = gen_vec(&mut rng, q, sb, n * k);
                    let gemm = match PackedGemm::new(Multiplier::CPU32, p, q, sgn, &bt, k, n) {
                        Ok(g) => g,
                        // A signed 1-bit operand set ({-1, 0}) is
                        // degenerate; tolerate infeasibility only there.
                        Err(_) if matches!(sgn, Signedness::Signed) && p.min(q) == 1 => continue,
                        Err(e) => panic!("no gemm design point for p={p} q={q} {sgn:?}: {e}"),
                    };
                    let lhs = gemm.pack_lhs(&a, m);
                    assert_seq_eq(&gemm.matmul(&lhs), &ref_matmul(&a, &bt, m, k, n))
                        .unwrap_or_else(|e| panic!("p={p} q={q} {sgn:?} k={k}: {e}"));
                }
            }
        }
    }
}

/// Determinism: 1-thread and N-thread tiled matmuls are bit-identical —
/// and identical to the serial kernel — on a matrix whose row count does
/// not divide evenly into tiles (and which is large enough to take the
/// parallel path, not the small-matrix serial cutoff).
#[test]
fn matmul_tiled_invariant_under_thread_count() {
    let (m, k, n) = (67usize, 131usize, 23usize);
    assert!((m * k * n) as u64 >= 100_000, "matrix too small to exercise tiling");
    let mut rng = Rng::new(0x6E89);
    let a = rng.quant_unsigned_vec(4, m * k);
    let bt = rng.quant_signed_vec(4, n * k);
    let gemm = PackedGemm::new(
        Multiplier::CPU32,
        4,
        4,
        Signedness::UnsignedBySigned,
        &bt,
        k,
        n,
    )
    .unwrap();
    let lhs = gemm.pack_lhs(&a, m);
    let serial = gemm.matmul(&lhs);
    assert_seq_eq(&serial, &ref_matmul(&a, &bt, m, k, n)).unwrap();
    for threads in [1usize, 2, 3, 5, 8, 16] {
        let tiled = gemm.matmul_tiled(&lhs, &ThreadPool::new(threads));
        assert_seq_eq(&tiled, &serial).unwrap_or_else(|e| panic!("threads={threads}: {e}"));
    }
}

/// Uneven explicit row tiles (and column tiles) compose to the full
/// matmul — the manual counterpart of the pool's chunking.
#[test]
fn uneven_tiles_compose_to_full_matmul() {
    let (m, k, n) = (7usize, 29usize, 5usize);
    let mut rng = Rng::new(0x6E8A);
    let a = rng.quant_unsigned_vec(4, m * k);
    let bt = rng.quant_signed_vec(4, n * k);
    let gemm = PackedGemm::new(
        Multiplier::CPU32,
        4,
        4,
        Signedness::UnsignedBySigned,
        &bt,
        k,
        n,
    )
    .unwrap();
    let lhs = gemm.pack_lhs(&a, m);
    let want = gemm.matmul(&lhs);

    // Row tiles of 3, 3 and 1 rows (row-major regions).
    let mut by_rows = vec![0i64; m * n];
    for (start, end) in [(0usize, 3usize), (3, 6), (6, 7)] {
        gemm.rows_into(&lhs, start, end, &mut by_rows[start * n..end * n]);
    }
    assert_seq_eq(&by_rows, &want).unwrap();

    // Column tiles of 2, 2 and 1 columns (col-major regions).
    let mut by_cols = vec![0i64; m * n];
    for (start, end) in [(0usize, 2usize), (2, 4), (4, 5)] {
        gemm.cols_into(&lhs, start, end, &mut by_cols[start * m..end * m]);
    }
    for row in 0..m {
        for col in 0..n {
            assert_eq!(by_cols[col * m + row], want[row * n + col], "({row},{col})");
        }
    }
}

/// Acceptance point: the paper's headline CPU design point (CPU32,
/// p = q = 4) must run the GEMM in the `i64` lane, not `i128` — for the
/// bare kernel and for the im2row layer built on it.
#[test]
fn cpu32_4bit_selects_the_i64_lane() {
    let gemm = PackedGemm::new(
        Multiplier::CPU32,
        4,
        4,
        Signedness::UnsignedBySigned,
        &[],
        0,
        0,
    )
    .unwrap();
    assert!(gemm.uses_fast_lane(), "{:?}", gemm.design_point());

    let shape = ConvShape {
        ci: 4,
        co: 2,
        hi: 5,
        wi: 9,
        k: 3,
    };
    let mut rng = Rng::new(0x6E8B);
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let eng = Im2RowConv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap();
    assert!(eng.gemm().uses_fast_lane(), "{:?}", eng.gemm().design_point());
    // A wider multiplier overflows the lane criterion and falls back.
    let wide = PackedGemm::new(Multiplier::CPU64, 4, 4, Signedness::Unsigned, &[], 0, 0).unwrap();
    assert!(!wide.uses_fast_lane());
}

/// The legacy `DotHiKonv::matmul` convenience API (now routed through
/// `PackedGemm`) stays exact against the scalar-block `dot` it falls
/// back to.
#[test]
fn dot_matmul_routing_stays_exact() {
    let mut rng = Rng::new(0x6E8C);
    for (p, q, sgn) in [
        (4u32, 4u32, Signedness::UnsignedBySigned),
        (3, 5, Signedness::Unsigned),
        (6, 2, Signedness::Signed),
    ] {
        let eng = DotHiKonv::new(Multiplier::CPU32, p, q, sgn).unwrap();
        let (m, k, n) = (6usize, 41usize, 3usize);
        let (sa, sb) = signed_operands(sgn);
        let a = gen_vec(&mut rng, p, sa, m * k);
        let bt = gen_vec(&mut rng, q, sb, n * k);
        let got = eng.matmul(&a, &bt, m, k, n);
        assert_seq_eq(&got, &ref_matmul(&a, &bt, m, k, n)).unwrap();
        // Scalar-block fallback agreement, dot by dot.
        for row in 0..m {
            for col in 0..n {
                assert_eq!(
                    got[row * n + col],
                    eng.dot(&a[row * k..(row + 1) * k], &bt[col * k..(col + 1) * k])
                );
            }
        }
    }
}

/// The im2row lowering through the pre-packed GEMM equals the reference
/// conv and is thread-count invariant on an unevenly-tiling layer.
#[test]
fn im2row_tiled_matches_reference_and_is_thread_invariant() {
    let shape = ConvShape {
        ci: 16,
        co: 13,
        hi: 8,
        wi: 30,
        k: 3,
    };
    assert!(shape.macs() >= 100_000, "shape too small to exercise tiling");
    let mut rng = Rng::new(0x6E8D);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let eng = Im2RowConv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap();
    let serial = eng.conv(&input);
    assert_seq_eq(&serial, &conv2d_ref(&input, &weights, shape)).unwrap();
    for threads in [1usize, 2, 3, 5, 8, 16] {
        let tiled = im2row_tiled(&eng, &ThreadPool::new(threads), &input);
        assert_seq_eq(&tiled, &serial).unwrap_or_else(|e| panic!("threads={threads}: {e}"));
    }
}
