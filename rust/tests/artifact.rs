//! AOT-artifact acceptance suite: `compile` → serialize → load must be
//! bit-identical to plan-at-startup for **every** zoo workload, must
//! perform zero weight packing on the load path (asserted via the
//! process-wide pack counter), and must reject corrupt/truncated/
//! mismatched files with precise errors — never panics — while a
//! host-signature mismatch degrades to re-planning, not failure.

use hikonv::artifact::{expected_host, load_runner, Artifact, LoadMode, ARTIFACT_VERSION};
use hikonv::engine::{EngineConfig, EnginePlan};
use hikonv::models::{random_graph_weights, zoo, GraphRunner};
use hikonv::packing::weight_pack_words;
use hikonv::testing::assert_seq_eq;
use hikonv::util::rng::Rng;

/// A deterministic engine config: explicit thread count keeps the host
/// signature machine-independent, so loads stay on the prepacked path.
fn engine() -> EngineConfig {
    EngineConfig::auto().with_threads(2)
}

#[test]
fn every_zoo_workload_round_trips_bit_exact() {
    for name in zoo::NAMES {
        let graph = zoo::build(name).unwrap();
        let weights = random_graph_weights(&graph, 0xA07).unwrap();
        let fresh = GraphRunner::new(graph.clone(), weights.clone(), engine())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let art = Artifact::compile(graph.clone(), weights, engine())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let bytes = art.to_bytes();
        let (loaded, mode) = Artifact::from_bytes(&bytes)
            .unwrap_or_else(|e| panic!("{name}: {e}"))
            .into_runner()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(mode, LoadMode::Prepacked, "{name}");
        // The loaded runner executes the identical plan...
        assert_eq!(loaded.plan().kernel_names(), fresh.plan().kernel_names(), "{name}");
        assert_eq!(loaded.plan().threads, fresh.plan().threads, "{name}");
        // ...with the identical calibrated shifts (stored, not re-derived)...
        assert_eq!(loaded.requant_shifts(), fresh.requant_shifts(), "{name}");
        // ...and is bit-identical to plan-at-startup, fused and unfused.
        let (c, h, w) = loaded.graph().input;
        let frames = if name == "ultranet" { 1 } else { 2 };
        let mut rng = Rng::new(0xF00D ^ c as u64);
        for _ in 0..frames {
            let frame = rng.quant_unsigned_vec(loaded.graph().input_bits, c * h * w);
            let got = loaded.infer(&frame);
            assert_seq_eq(&got, &fresh.infer_unfused(&frame))
                .unwrap_or_else(|e| panic!("{name} vs unfused: {e}"));
            assert_seq_eq(&got, &fresh.infer(&frame))
                .unwrap_or_else(|e| panic!("{name} vs fused: {e}"));
        }
    }
}

#[test]
fn loading_skips_the_planner_and_all_weight_packing() {
    let graph = zoo::build("fc-head").unwrap();
    let weights = random_graph_weights(&graph, 0xA07).unwrap();
    // Compiling packs (that is the point: pay it once, offline)...
    let before_compile = weight_pack_words();
    let art = Artifact::compile(graph, weights, engine()).unwrap();
    assert!(
        weight_pack_words() > before_compile,
        "compile must go through the packing path"
    );
    let bytes = art.to_bytes();
    // ...and loading must not pack a single word.
    let before_load = weight_pack_words();
    let (runner, mode) = Artifact::from_bytes(&bytes).unwrap().into_runner().unwrap();
    assert_eq!(mode, LoadMode::Prepacked);
    assert_eq!(
        weight_pack_words(),
        before_load,
        "prepacked load must not repack weights"
    );
    // The runner is immediately serviceable.
    let (c, h, w) = runner.graph().input;
    let frame = vec![3i64; c * h * w];
    assert_eq!(runner.infer(&frame).len(), runner.head_len());
}

#[test]
fn embedded_plan_matches_a_fresh_plan_byte_for_byte() {
    for name in ["ultranet-tiny", "strided", "mixed"] {
        let graph = zoo::build(name).unwrap();
        let weights = random_graph_weights(&graph, 0xA07).unwrap();
        let art = Artifact::compile(graph.clone(), weights, engine()).unwrap();
        let replanned = EnginePlan::plan_graph(&graph, &engine()).unwrap();
        assert_eq!(
            art.plan.to_json().to_string_pretty(),
            replanned.to_json().to_string_pretty(),
            "{name}"
        );
        assert_eq!(art.host, expected_host(&engine()), "{name}");
    }
}

#[test]
fn every_truncated_prefix_is_an_error_never_a_panic() {
    let graph = zoo::build("residual").unwrap();
    let weights = random_graph_weights(&graph, 5).unwrap();
    let bytes = Artifact::compile(graph, weights, engine()).unwrap().to_bytes();
    // Every header prefix, then a stride through the payload, then the
    // one-byte-short file: all must fail cleanly.
    let mut cuts: Vec<usize> = (0..20.min(bytes.len())).collect();
    cuts.extend((20..bytes.len()).step_by(97));
    cuts.push(bytes.len() - 1);
    for cut in cuts {
        match Artifact::from_bytes(&bytes[..cut]) {
            Err(_) => {}
            Ok(_) => panic!("truncation to {cut}/{} bytes decoded", bytes.len()),
        }
    }
}

#[test]
fn every_single_byte_flip_is_detected() {
    let graph = zoo::build("residual").unwrap();
    let weights = random_graph_weights(&graph, 5).unwrap();
    let bytes = Artifact::compile(graph, weights, engine()).unwrap().to_bytes();
    // Header bytes exhaustively, payload on a stride: the magic, version
    // and checksum checks must catch every flip.
    let mut positions: Vec<usize> = (0..20).collect();
    positions.extend((20..bytes.len()).step_by(61));
    for pos in positions {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x01;
        assert!(
            Artifact::from_bytes(&corrupt).is_err(),
            "flip at byte {pos} went undetected"
        );
    }
}

#[test]
fn version_mismatch_is_a_precise_error() {
    let graph = zoo::build("ultranet-tiny").unwrap();
    let weights = random_graph_weights(&graph, 5).unwrap();
    let mut bytes = Artifact::compile(graph, weights, engine()).unwrap().to_bytes();
    bytes[8..12].copy_from_slice(&(ARTIFACT_VERSION + 1).to_le_bytes());
    let err = Artifact::from_bytes(&bytes).unwrap_err().to_string();
    assert!(
        err.contains(&format!("version {}", ARTIFACT_VERSION + 1)),
        "{err}"
    );
    assert!(err.contains("recompile"), "{err}");
}

#[test]
fn host_mismatch_falls_back_to_replanning_and_stays_exact() {
    let graph = zoo::build("fc-head").unwrap();
    let weights = random_graph_weights(&graph, 0xA07).unwrap();
    let fresh = GraphRunner::new(graph.clone(), weights.clone(), engine()).unwrap();
    let mut art = Artifact::compile(graph, weights, engine()).unwrap();
    art.host = "threads=511;lane=64".to_string();
    // Round-trip through bytes so the tampered host is really on disk.
    let (runner, mode) = Artifact::from_bytes(&art.to_bytes())
        .unwrap()
        .into_runner()
        .unwrap();
    match mode {
        LoadMode::Replanned(reason) => assert!(reason.contains("threads=511"), "{reason}"),
        other => panic!("expected Replanned, got {other:?}"),
    }
    let (c, h, w) = runner.graph().input;
    let mut rng = Rng::new(0xCAFE);
    let frame = rng.quant_unsigned_vec(runner.graph().input_bits, c * h * w);
    assert_seq_eq(&runner.infer(&frame), &fresh.infer(&frame)).unwrap();
}

#[test]
fn file_round_trip_and_load_runner_helper() {
    let graph = zoo::build("mixed").unwrap();
    let weights = random_graph_weights(&graph, 11).unwrap();
    let art = Artifact::compile(graph, weights, engine()).unwrap();
    let path = std::env::temp_dir().join(format!("hikonv_artifact_test_{}.hkv", std::process::id()));
    art.write(&path).unwrap();
    let (runner, mode) = load_runner(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert_eq!(mode, LoadMode::Prepacked);
    assert_eq!(runner.graph().name, "mixed-ultranet");
    // A missing file is a readable error, not a panic.
    let err = load_runner(&path).unwrap_err().to_string();
    assert!(err.contains("read"), "{err}");
}
