//! Acceptance suite for the supervised multi-model serving runtime
//! (ISSUE 8): tenant isolation under scripted faults, restart budgets
//! escalating to quarantine, and hot artifact reload — atomic swap on
//! success, rollback with a recorded reason on a corrupt replacement.
//!
//! The oracle pattern mirrors `serve_chaos.rs`: a fault-free run of the
//! same seeded configuration is the ground truth, and the healthy
//! tenant's detections must match it bit-for-bit.

use hikonv::artifact::Artifact;
use hikonv::coordinator::{
    serve_registry, ModelRegistry, MultiServeConfig, ReloadAt, TenantState,
};
use hikonv::engine::EngineConfig;
use hikonv::models::{random_graph_weights, zoo};
use std::path::PathBuf;
use std::time::Duration;

fn cfg() -> EngineConfig {
    EngineConfig::auto().with_threads(1)
}

/// Two-tenant registry with per-tenant weights; registration order is
/// part of the oracle (it fixes each tenant's source seed).
fn two_tenants() -> ModelRegistry {
    let mut reg = ModelRegistry::new(cfg());
    for (i, name) in ["a", "b"].iter().enumerate() {
        let g = zoo::fc_head();
        let w = random_graph_weights(&g, 20 + i as u64).unwrap();
        reg.register_graph(name, g, w).unwrap();
    }
    reg
}

fn tmp_artifact(tag: &str, seed: u64) -> PathBuf {
    let dir = std::env::temp_dir().join("hikonv_registry_serve_tests");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(format!("{tag}.hkv"));
    let g = zoo::fc_head();
    let w = random_graph_weights(&g, seed).unwrap();
    Artifact::compile(g, w, cfg()).unwrap().write(&path).unwrap();
    path
}

#[test]
fn faulty_tenant_quarantines_while_the_other_stays_bit_exact() {
    let chaos = MultiServeConfig {
        frames: 24,
        queue_depth: 4,
        max_batch: 1,
        max_retries: 0,
        restart_budget: 2,
        restart_backoff: Duration::from_millis(2),
        // Three cursed single-frame batches for tenant a: two restarts,
        // then the budget is spent and a is quarantined. Tenant b is
        // never targeted.
        fault_plan: "panic@1:model=a;panic@2:model=a;panic@3:model=a"
            .parse()
            .unwrap(),
        ..MultiServeConfig::default()
    };
    let mut reg = two_tenants();
    let report = serve_registry(&mut reg, &chaos).unwrap();

    // Tenant a: restarted under backoff, then quarantined with the
    // reason surfaced — and every admitted frame still accounted for.
    let a = report.tenant("a").unwrap();
    assert_eq!(a.state, "quarantined");
    assert_eq!(a.restarts, 2);
    assert!(a.slo.accounted(), "a identity violated: {:?}", a.slo);
    let reason = a.quarantine_reason.as_deref().unwrap();
    assert!(reason.contains("restart budget (2) exhausted"), "{reason}");
    assert!(a.faults.iter().any(|f| f.kind == "panic"));
    assert!(a.faults.iter().any(|f| f.kind == "restart"));
    assert!(a.faults.iter().any(|f| f.kind == "quarantine"));
    assert_eq!(reg.tenant("a").unwrap().state, TenantState::Quarantined);

    // Tenant b: untouched — full completion, zero faults, zero restarts.
    let b = report.tenant("b").unwrap();
    assert_eq!(b.state, "drained");
    assert_eq!(b.restarts, 0);
    assert_eq!(b.slo.completed, 24);
    assert!(b.slo.accounted(), "b identity violated: {:?}", b.slo);
    assert!(b.faults.is_empty(), "faults leaked into b: {:?}", b.faults);

    // Bit-exactness: b's detections equal a fault-free run's.
    let mut clean_reg = two_tenants();
    let clean = serve_registry(
        &mut clean_reg,
        &MultiServeConfig {
            fault_plan: Default::default(),
            ..chaos
        },
    )
    .unwrap();
    let clean_b = clean.tenant("b").unwrap();
    assert_eq!(clean_b.slo.completed, 24);
    assert_eq!(
        b.detections, clean_b.detections,
        "tenant b's detections drifted under tenant a's faults"
    );
}

#[test]
fn hot_reload_swaps_atomically_with_no_dropped_or_double_served_frames() {
    // The replacement artifact is compiled from the same graph + weights
    // the tenant is serving, so a correct swap is invisible in the
    // detections — any drop, duplicate, or drift is the runtime's fault.
    let g = zoo::fc_head();
    let w = random_graph_weights(&g, 33).unwrap();
    let path = std::env::temp_dir().join("hikonv_registry_serve_tests");
    std::fs::create_dir_all(&path).unwrap();
    let path = path.join("same_model.hkv");
    Artifact::compile(g.clone(), w.clone(), cfg())
        .unwrap()
        .write(&path)
        .unwrap();

    let base = MultiServeConfig {
        frames: 24,
        source_fps_cap: Some(500.0), // ~48 ms run: the trigger fires mid-stream
        queue_depth: 4,
        max_batch: 2,
        ..MultiServeConfig::default()
    };

    let mut reg = ModelRegistry::new(cfg());
    reg.register_graph("a", g.clone(), w.clone()).unwrap();
    let report = serve_registry(
        &mut reg,
        &MultiServeConfig {
            reload_at: Some(ReloadAt {
                after_admitted: 8,
                tenant: "a".into(),
                path: path.clone(),
            }),
            ..base.clone()
        },
    )
    .unwrap();

    let a = report.tenant("a").unwrap();
    assert_eq!(a.reloads, 1, "reload must have fired: {:?}", a.faults);
    assert_eq!(a.reload_failures, 0);
    assert_eq!(a.state, "drained");
    assert!(a.slo.accounted(), "identity violated: {:?}", a.slo);
    assert_eq!(a.slo.completed, 24, "no frame dropped across the swap");
    let mut ids: Vec<u64> = a.detections.iter().map(|d| d.frame_id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 24, "no frame double-served across the swap");
    assert!(a.faults.iter().any(|f| f.kind == "reload"));

    // Bit-exact against a no-reload run of the same configuration.
    let mut clean_reg = ModelRegistry::new(cfg());
    clean_reg.register_graph("a", g, w).unwrap();
    let clean = serve_registry(&mut clean_reg, &base).unwrap();
    assert_eq!(
        report.tenant("a").unwrap().detections,
        clean.tenant("a").unwrap().detections,
        "detections drifted across an identical-model hot reload"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupt_replacement_artifact_rolls_back_with_recorded_reason() {
    let good = tmp_artifact("corrupt_src", 44);
    let mut bytes = std::fs::read(&good).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0xff; // payload corruption: the checksum must catch it
    let bad = good.with_file_name("corrupt.hkv");
    std::fs::write(&bad, &bytes).unwrap();

    let g = zoo::fc_head();
    let w = random_graph_weights(&g, 44).unwrap();
    let mut reg = ModelRegistry::new(cfg());
    reg.register_graph("a", g, w).unwrap();
    let report = serve_registry(
        &mut reg,
        &MultiServeConfig {
            frames: 24,
            source_fps_cap: Some(500.0),
            queue_depth: 4,
            reload_at: Some(ReloadAt {
                after_admitted: 8,
                tenant: "a".into(),
                path: bad.clone(),
            }),
            ..MultiServeConfig::default()
        },
    )
    .unwrap();

    // Never a panic: the run completes on the old runner, the rejected
    // artifact is quarantined with the reason recorded.
    let a = report.tenant("a").unwrap();
    assert_eq!(a.state, "drained", "tenant keeps serving the old runner");
    assert_eq!(a.reloads, 0);
    assert_eq!(a.reload_failures, 1);
    assert_eq!(a.slo.completed, 24);
    assert!(a.slo.accounted(), "identity violated: {:?}", a.slo);
    let reason = a.quarantine_reason.as_deref().unwrap();
    assert!(
        reason.contains("checksum") && reason.contains("corrupt.hkv"),
        "quarantine reason must name the artifact and failure: {reason}"
    );
    assert!(a
        .faults
        .iter()
        .any(|f| f.kind == "reload" && f.detail.contains("checksum")));
    std::fs::remove_file(&good).ok();
    std::fs::remove_file(&bad).ok();
}

#[test]
fn repeat_registrations_reuse_the_compiled_plan() {
    let g = zoo::fc_head();
    let w = random_graph_weights(&g, 55).unwrap();
    let mut reg = ModelRegistry::new(cfg());
    reg.register_graph("a", g.clone(), w.clone()).unwrap();
    reg.register_graph("b", g, w).unwrap();
    assert_eq!(reg.cache_hits(), 1, "identical model must hit the plan cache");
    // Both tenants still serve independently.
    let report = serve_registry(
        &mut reg,
        &MultiServeConfig {
            frames: 8,
            ..MultiServeConfig::default()
        },
    )
    .unwrap();
    assert!(report.accounted());
    assert_eq!(report.total_completed(), 16);
}
