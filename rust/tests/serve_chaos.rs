//! Chaos suite for the overload-safe serve path (ISSUE 7 acceptance):
//! scripted fault plans + overload must leave `serve()` returning
//! `Ok(report)` with every admitted frame accounted for exactly once
//! (`admitted == shed + expired + failed + completed`), zero process
//! panics, completed detections bit-exact with a fault-free run, and
//! counters deterministic across identically-seeded runs.

use hikonv::coordinator::pipeline::{CpuBackend, Detection};
use hikonv::coordinator::{
    serve, AdmissionPolicy, FaultInjector, FaultPlan, Frame, InferBackend, ServeConfig,
    ServeReport,
};
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::theory::Multiplier;
use std::collections::HashMap;
use std::time::Duration;

/// Trivially fast backend for schedule-focused chaos tests.
struct Echo;
impl InferBackend for Echo {
    fn name(&self) -> &str {
        "echo"
    }
    fn input_dims(&self) -> (usize, usize, usize) {
        (1, 2, 2)
    }
    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        frames
            .iter()
            .map(|f| Detection {
                frame_id: f.id,
                cell: (0, 0),
            })
            .collect()
    }
}

/// Slow backend: fixed service time per batch.
struct Slow {
    per_batch: Duration,
}
impl InferBackend for Slow {
    fn name(&self) -> &str {
        "slow"
    }
    fn input_dims(&self) -> (usize, usize, usize) {
        (1, 2, 2)
    }
    fn infer_batch(&mut self, frames: &[Frame]) -> Vec<Detection> {
        std::thread::sleep(self.per_batch);
        frames
            .iter()
            .map(|f| Detection {
                frame_id: f.id,
                cell: (0, 0),
            })
            .collect()
    }
}

fn hikonv_backend(seed: u64) -> Box<dyn InferBackend> {
    let model = ultranet_tiny();
    let weights = random_weights(&model, seed);
    let runner = CpuRunner::new(model, weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
    Box::new(CpuBackend::new(runner))
}

/// The scripted chaos schedule: 100 fps pacing, queue depth 8, and a
/// 1.5 s stall on the first batch so the producer (done at ~640 ms)
/// overflows the queue deterministically — frames 0–11 reach inference,
/// frames 12–63 are shed at admission.
fn chaos_config() -> ServeConfig {
    ServeConfig {
        frames: 64,
        source_fps_cap: Some(100.0),
        queue_depth: 8,
        max_batch: 4,
        linger: Duration::from_millis(300),
        seed: 7,
        bits: 4,
        policy: AdmissionPolicy::Shed,
        max_retries: 2,
        retry_backoff: Duration::from_millis(1),
        degrade_after: 100,
        ..ServeConfig::default()
    }
}

fn chaos_plan() -> FaultPlan {
    "stall@0:1500ms;panic@4;drop@8;dup@9;misorder@10".parse().unwrap()
}

fn run_chaos() -> ServeReport {
    let faulty = FaultInjector::new(hikonv_backend(7), chaos_plan());
    serve(Box::new(faulty), &chaos_config()).unwrap()
}

#[test]
fn scripted_faults_account_every_frame_and_stay_bit_exact() {
    let report = run_chaos();

    // Every admitted frame accounted for exactly once.
    assert!(report.slo.accounted(), "identity violated: {:?}", report.slo);
    assert_eq!(report.slo.admitted, 64);
    // The stall pins the consumer while the producer overruns the queue:
    // most frames are shed, nothing expires (no deadline set).
    assert!(report.slo.shed > 0, "stall must force shedding");
    assert_eq!(report.slo.expired, 0);
    // drop@8 is the only unrecoverable frame.
    assert_eq!(report.slo.failed, 1);
    assert!(report.detections.iter().all(|d| d.frame_id != 8));
    // panic@4 is retried once and then succeeds.
    assert_eq!(report.slo.retried, 1);
    // Faults: one caught panic + one detection-stream mismatch.
    assert_eq!(report.slo.faults, 2);
    assert!(report.faults.iter().any(|f| f.kind == "panic"));
    assert!(report.faults.iter().any(|f| f.kind == "mismatch"));
    assert!(report.slo.completed >= 8, "slo: {:?}", report.slo);

    // Bit-exactness: every completed frame's detection equals the
    // fault-free run's detection for that frame.
    let clean = serve(
        hikonv_backend(7),
        &ServeConfig {
            frames: 64,
            seed: 7,
            bits: 4,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert_eq!(clean.slo.completed, 64);
    let oracle: HashMap<u64, (usize, usize)> = clean
        .detections
        .iter()
        .map(|d| (d.frame_id, d.cell))
        .collect();
    for det in &report.detections {
        assert_eq!(
            oracle.get(&det.frame_id),
            Some(&det.cell),
            "frame {} detection drifted under faults",
            det.frame_id
        );
    }
}

#[test]
fn chaos_counters_are_deterministic_across_seeded_runs() {
    let a = run_chaos();
    let b = run_chaos();
    assert_eq!(a.slo, b.slo, "SLO counters must be reproducible");
    assert_eq!(a.detections, b.detections, "detections must be reproducible");
    assert_eq!(a.batches, b.batches);
}

#[test]
fn overload_4x_feeder_cap_sheds_and_returns_ok() {
    // Service capacity ~100 fps (10 ms per single-frame batch); offered
    // load 400 fps = 4x. The shed policy must keep the queue bounded and
    // the run must finish cleanly with the identity intact.
    let report = serve(
        Box::new(Slow {
            per_batch: Duration::from_millis(10),
        }),
        &ServeConfig {
            frames: 80,
            source_fps_cap: Some(400.0),
            queue_depth: 4,
            max_batch: 1,
            linger: Duration::ZERO,
            seed: 3,
            bits: 4,
            policy: AdmissionPolicy::Shed,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert!(report.slo.accounted(), "identity violated: {:?}", report.slo);
    assert_eq!(report.slo.admitted, 80);
    assert!(report.slo.shed > 0, "4x overload must shed");
    assert!(report.slo.completed > 0, "pipeline must stay live");
}

#[test]
fn retry_exhaustion_fails_only_the_cursed_batch() {
    // panic@0 fires on all three attempts (1 try + 2 retries): the batch
    // holding frame 0 fails; everything else completes.
    let plan: FaultPlan = "panic@0:x3".parse().unwrap();
    let report = serve(
        Box::new(FaultInjector::new(Box::new(Echo), plan)),
        &ServeConfig {
            frames: 6,
            max_batch: 1,
            linger: Duration::ZERO,
            max_retries: 2,
            retry_backoff: Duration::from_micros(200),
            degrade_after: 100,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert_eq!(report.slo.failed, 1);
    assert_eq!(report.slo.completed, 5);
    assert_eq!(report.slo.faults, 3);
    assert_eq!(report.slo.retried, 2);
    assert!(report.slo.accounted());
    assert!(report.detections.iter().all(|d| d.frame_id != 0));
}

#[test]
fn stall_expires_queued_frames_as_expired_not_failed() {
    // A 400 ms stall on the first batch pins the consumer while every
    // other frame's 60 ms deadline lapses in the queue. Those frames
    // must be accounted as `expired` (shed pre-inference by the
    // batcher), never as `failed` — and the identity must hold.
    let plan: FaultPlan = "stall@0:400ms".parse().unwrap();
    let report = serve(
        Box::new(FaultInjector::new(Box::new(Echo), plan)),
        &ServeConfig {
            frames: 12,
            queue_depth: 16, // deep enough that Block never waits
            max_batch: 1,
            linger: Duration::ZERO,
            policy: AdmissionPolicy::Block,
            deadline: Some(Duration::from_millis(60)),
            degrade_after: 100,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert!(report.slo.accounted(), "identity violated: {:?}", report.slo);
    assert_eq!(report.slo.admitted, 12);
    assert_eq!(
        report.slo.failed, 0,
        "expiry must never masquerade as failure: {:?}",
        report.slo
    );
    assert_eq!(report.slo.shed, 0, "Block admission sheds nothing at the door");
    assert!(
        report.slo.expired >= 10,
        "stall must expire queued frames: {:?}",
        report.slo
    );
    assert_eq!(report.slo.completed + report.slo.expired, 12);
    // Frame 0 itself completes (stalled, not expired): its lateness is a
    // deadline miss, not an expiry.
    assert!(report.detections.iter().any(|d| d.frame_id == 0));
    assert!(report.slo.deadline_misses >= 1);
}

#[test]
fn drop_oldest_policy_always_serves_the_freshest_frame() {
    let report = serve(
        Box::new(Slow {
            per_batch: Duration::from_millis(8),
        }),
        &ServeConfig {
            frames: 30,
            queue_depth: 2,
            max_batch: 1,
            linger: Duration::ZERO,
            seed: 5,
            bits: 4,
            policy: AdmissionPolicy::DropOldest,
            ..ServeConfig::default()
        },
    )
    .unwrap();
    assert!(report.slo.accounted());
    assert_eq!(report.slo.admitted, 30);
    // Eviction drops the *oldest* queued frame, so the newest frame is
    // always still in the queue when the producer closes — it must serve.
    assert!(
        report.detections.iter().any(|d| d.frame_id == 29),
        "freshest frame must complete under drop-oldest"
    );
}
