//! Steady-state allocation accounting for the fused pipeline: after the
//! first (warm-up) frame, `CpuRunner::infer_into` on a serial engine must
//! perform **zero heap allocations** — every buffer comes from the
//! runner's arena. Asserted with a counting global allocator.
//!
//! This file intentionally holds a single test: the counter is global to
//! the test binary, and a concurrently-running neighbour test would
//! pollute it.

use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::theory::Multiplier;
use hikonv::util::rng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Passes every call through to [`System`], counting allocation events
/// (alloc / alloc_zeroed / grow-realloc) while `COUNTING` is set.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn count_infer_allocs(kind: EngineKind, seed: u64) -> u64 {
    let model = ultranet_tiny();
    let weights = random_weights(&model, seed);
    let runner = CpuRunner::new(model.clone(), weights, kind).unwrap();
    let (c, h, w) = model.input;
    let mut rng = Rng::new(seed ^ 0xA110C);
    let warm_a = rng.quant_unsigned_vec(4, c * h * w);
    let warm_b = rng.quant_unsigned_vec(4, c * h * w);
    let frame = rng.quant_unsigned_vec(4, c * h * w);
    let mut head = vec![0i64; runner.head_len()];
    // Warm the arena (first frames may size packed buffers and grow the
    // free-list's own vector).
    runner.infer_into(&warm_a, &mut head);
    runner.infer_into(&warm_b, &mut head);
    // Steady state: count.
    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    runner.infer_into(&frame, &mut head);
    COUNTING.store(false, Ordering::SeqCst);
    ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_infer_performs_zero_heap_allocations() {
    // Serial engines only: intra-layer tiling spawns scoped workers per
    // layer, which inherently allocates (thread stacks, chunk queue) —
    // the zero-alloc contract is the serial/serving-worker path.
    for (kind, seed) in [
        (EngineKind::HiKonv(Multiplier::CPU32), 401u64),
        (EngineKind::Im2Row(Multiplier::CPU32, 1), 402),
    ] {
        let allocs = count_infer_allocs(kind, seed);
        assert_eq!(
            allocs, 0,
            "{kind:?}: steady-state infer_into allocated {allocs} times"
        );
    }
}
