//! `EngineConfig` / `Multiplier` grammar round-trip property tests: the
//! textual form serve configs and bench JSON labels carry can never
//! drift from the parser, because `Display` output always parses back to
//! an equal config.

use hikonv::engine::{EngineConfig, KernelChoice};
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::rng::Rng;

#[test]
fn multiplier_round_trip_property() {
    let mut rng = Rng::new(0xC0DE);
    for _ in 0..500 {
        let m = Multiplier::new(1 + rng.below(64) as u32, 1 + rng.below(64) as u32);
        assert_eq!(m.to_string().parse::<Multiplier>().unwrap(), m);
    }
}

#[test]
fn engine_config_round_trip_property() {
    let mut rng = Rng::new(0x5EED);
    let names = ["baseline", "hikonv", "hikonv-tiled", "im2row"];
    let mults = [Multiplier::CPU32, Multiplier::CPU64, Multiplier::DSP48E2];
    let signs = [
        Signedness::Unsigned,
        Signedness::Signed,
        Signedness::UnsignedBySigned,
    ];
    for _ in 0..1000 {
        let mut cfg = if rng.below(5) == 0 {
            EngineConfig::auto()
        } else {
            EngineConfig::named(names[rng.below(names.len() as u64) as usize])
        };
        if rng.below(2) == 0 {
            cfg = cfg.with_multiplier(mults[rng.below(mults.len() as u64) as usize]);
        }
        if rng.below(2) == 0 {
            cfg = cfg.with_threads(1 + rng.below(64) as usize);
        }
        if rng.below(3) == 0 {
            cfg = cfg.with_bits(1 + rng.below(8) as u32, 1 + rng.below(8) as u32);
        }
        if rng.below(3) == 0 {
            cfg = cfg.with_signedness(signs[rng.below(signs.len() as u64) as usize]);
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_tile_co(1 + rng.below(32) as usize);
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_channel_block(1 + rng.below(64) as usize);
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_lane_bits(if rng.below(2) == 0 { 64 } else { 128 });
        }
        if rng.below(4) == 0 {
            cfg = cfg.with_probe(true);
        }
        let rendered = cfg.to_string();
        let parsed: EngineConfig = rendered
            .parse()
            .unwrap_or_else(|e| panic!("'{rendered}' failed to parse back: {e}"));
        assert_eq!(parsed, cfg, "round trip of '{rendered}'");
    }
}

#[test]
fn legacy_spellings_still_parse() {
    // The four old `--engine` names are valid one-token specs.
    for name in ["baseline", "hikonv", "hikonv-tiled", "im2row"] {
        let cfg: EngineConfig = name.parse().unwrap();
        assert_eq!(cfg.kernel_name(), Some(name));
        assert_eq!(cfg.to_string(), name);
    }
    assert_eq!(
        "auto".parse::<EngineConfig>().unwrap().kernel,
        KernelChoice::Auto
    );
}

#[test]
fn whitespace_and_aliases_normalize() {
    let a: EngineConfig = " hikonv-tiled@cpu64 : threads=4 , tile-co=8 ".parse().unwrap();
    let b: EngineConfig = "hikonv-tiled@64x64:threads=4,tile-co=8".parse().unwrap();
    assert_eq!(a, b);
    // Canonical re-rendering is stable (idempotent round trip).
    assert_eq!(a.to_string().parse::<EngineConfig>().unwrap(), a);
}
