//! PJRT runtime integration: load the AOT artifacts produced by the Python
//! compile path and execute them from Rust, cross-checking numerics against
//! the native engines.
//!
//! Requires `make artifacts`. Tests are skipped (with a notice) when the
//! artifacts are missing so `cargo test` stays runnable pre-build.

use hikonv::conv::conv1d_ref;
use hikonv::runtime::{artifacts, artifacts_dir, Runtime};
use hikonv::theory::{solve, AccumMode, Multiplier, Signedness};
use hikonv::util::rng::Rng;

fn artifacts_present() -> bool {
    let ok = artifacts_dir().join(artifacts::HIKONV_CONV1D).exists();
    if !ok {
        eprintln!("skipping PJRT test: artifacts missing (run `make artifacts`)");
    }
    ok
}

/// The conv1d artifacts' fixed shapes (python/compile/aot.py).
const LEN: usize = 4096;
const TAPS: usize = 3;

#[test]
fn pjrt_client_comes_up() {
    let rt = Runtime::cpu().expect("PJRT CPU client");
    assert!(!rt.platform().is_empty());
}

#[test]
fn hikonv_conv1d_artifact_matches_native_reference() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_artifact(artifacts::HIKONV_CONV1D).unwrap();
    let mut rng = Rng::new(101);
    let f: Vec<i64> = rng.quant_unsigned_vec(4, LEN);
    let g: Vec<i64> = rng.quant_unsigned_vec(4, TAPS);
    let fi: Vec<i32> = f.iter().map(|&v| v as i32).collect();
    let gi: Vec<i32> = g.iter().map(|&v| v as i32).collect();
    let outs = model
        .run_i32(&[(fi, vec![LEN as i64]), (gi, vec![TAPS as i64])])
        .unwrap();
    let want = conv1d_ref(&f, &g);
    assert_eq!(outs[0].len(), want.len());
    for (i, (a, b)) in outs[0].iter().zip(&want).enumerate() {
        assert_eq!(*a as i64, *b, "index {i}");
    }
}

#[test]
fn hikonv_and_ref_artifacts_agree_with_each_other() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let hik = rt.load_artifact(artifacts::HIKONV_CONV1D).unwrap();
    let rf = rt.load_artifact(artifacts::REF_CONV1D).unwrap();
    let mut rng = Rng::new(202);
    for _ in 0..3 {
        let f: Vec<i32> = (0..LEN).map(|_| rng.quant_unsigned(4) as i32).collect();
        let g: Vec<i32> = (0..TAPS).map(|_| rng.quant_unsigned(4) as i32).collect();
        let a = hik
            .run_i32(&[(f.clone(), vec![LEN as i64]), (g.clone(), vec![TAPS as i64])])
            .unwrap();
        let b = rf
            .run_i32(&[(f, vec![LEN as i64]), (g, vec![TAPS as i64])])
            .unwrap();
        assert_eq!(a[0], b[0]);
    }
}

#[test]
fn hikonv_artifact_matches_native_hikonv_engine() {
    if !artifacts_present() {
        return;
    }
    // The packed kernel inside the artifact and the Rust packed engine use
    // the same design point (S=10, N=3, K=3): outputs must be identical.
    let dp = solve(
        Multiplier::CPU32,
        4,
        4,
        Signedness::Unsigned,
        AccumMode::Extended { m: 1 },
    )
    .unwrap();
    assert_eq!((dp.s, dp.n, dp.k), (10, 3, 3));
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_artifact(artifacts::HIKONV_CONV1D).unwrap();
    let mut rng = Rng::new(303);
    let f: Vec<i64> = rng.quant_unsigned_vec(4, LEN);
    let g: Vec<i64> = rng.quant_unsigned_vec(4, TAPS);
    let native = hikonv::conv::conv1d_hikonv(&f, &g, &dp);
    let fi: Vec<i32> = f.iter().map(|&v| v as i32).collect();
    let gi: Vec<i32> = g.iter().map(|&v| v as i32).collect();
    let outs = model
        .run_i32(&[(fi, vec![LEN as i64]), (gi, vec![TAPS as i64])])
        .unwrap();
    for (a, b) in outs[0].iter().zip(&native) {
        assert_eq!(*a as i64, *b);
    }
}

#[test]
fn ultranet_tiny_artifact_runs_and_is_deterministic() {
    if !artifacts_present() {
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let model = rt.load_artifact(artifacts::ULTRANET_TINY).unwrap();
    let mut rng = Rng::new(404);
    let frame: Vec<i32> = (0..3 * 40 * 80)
        .map(|_| rng.quant_unsigned(4) as i32)
        .collect();
    let a = model.run_i32(&[(frame.clone(), vec![3, 40, 80])]).unwrap();
    let b = model.run_i32(&[(frame, vec![3, 40, 80])]).unwrap();
    assert_eq!(a[0].len(), 36 * 5 * 10);
    assert_eq!(a[0], b[0]);
    assert!(a[0].iter().any(|&v| v != 0), "all-zero head output");
}
