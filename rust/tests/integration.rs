//! Cross-module integration: theory → packing → conv engines → DSP model →
//! models, exercised together the way the experiments use them.

use hikonv::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use hikonv::conv::reference::{conv2d_ref, ConvShape};
use hikonv::conv::{conv1d_hikonv, conv1d_ref};
use hikonv::dsp::dsp48e2::hikonv_fnk_on_dsp;
use hikonv::dsp::Dsp48e2;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::models::ultranet::{ultranet, ultranet_tiny};
use hikonv::theory::{solve, surface, AccumMode, Multiplier, Signedness};
use hikonv::util::rng::Rng;

/// The solver's Figure-5 surface points all execute exactly: for every
/// (p, q) in the 27×18 unsigned surface, the design point's packing runs
/// on the bit-accurate DSP model and reproduces the reference convolution.
#[test]
fn every_dsp_surface_point_executes_exactly() {
    let mut rng = Rng::new(1);
    let mut dsp = Dsp48e2::new();
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            let dp = solve(
                Multiplier::DSP48E2_UNSIGNED,
                p,
                q,
                Signedness::Unsigned,
                AccumMode::Single,
            )
            .unwrap();
            for _ in 0..10 {
                let f = rng.quant_unsigned_vec(p, dp.n);
                let g = rng.quant_unsigned_vec(q, dp.k);
                let y = hikonv_fnk_on_dsp(&mut dsp, &f, &g, dp.s, false).unwrap();
                assert_eq!(y, conv1d_ref(&f, &g), "p={p} q={q} {dp:?}");
            }
        }
    }
    assert!(!dsp.input_overflowed());
}

/// Throughput model consistency: ops/mult of the solved point equals the
/// operations the executed convolution actually performs.
#[test]
fn throughput_accounting_matches_execution() {
    let dp = solve(
        Multiplier::DSP48E2,
        4,
        4,
        Signedness::Unsigned,
        AccumMode::Single,
    )
    .unwrap();
    // F_{N,K} computes N*K products and (N-1)(K-1) accumulations:
    let f = vec![1i64; dp.n];
    let g = vec![1i64; dp.k];
    let y = conv1d_ref(&f, &g);
    let mults = dp.n * dp.k;
    let adds: usize = y.iter().map(|&v| (v as usize).saturating_sub(1)).sum();
    assert_eq!(dp.ops_per_mult(), (mults + adds) as u64);
}

/// End-to-end UltraNet-tiny: baseline vs HiKonv runners agree on every
/// frame of a small stream, and detections are deterministic.
#[test]
fn ultranet_tiny_stream_agreement() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 42);
    let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
    let hik = CpuRunner::new(
        model.clone(),
        weights,
        EngineKind::HiKonv(Multiplier::CPU32),
    )
    .unwrap();
    let (c, h, w) = model.input;
    let mut rng = Rng::new(2);
    for frame_i in 0..3 {
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        let b = hik.infer(&frame);
        assert_eq!(a, b, "frame {frame_i}");
    }
}

/// The full UltraNet final layer (Fig. 6b workload) is exact on HiKonv.
#[test]
fn ultranet_final_layer_exact() {
    let layer = &ultranet().layers[7];
    let shape = layer.padded_shape();
    let mut rng = Rng::new(3);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let eng = Conv2dHiKonv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap();
    assert_eq!(eng.conv(&input), conv2d_ref(&input, &weights, shape));
}

/// 64-bit multiplier engines (the i128 path) handle an 8-bit workload.
#[test]
fn cpu64_8bit_end_to_end() {
    let dp = solve(
        Multiplier::CPU64,
        8,
        8,
        Signedness::Unsigned,
        AccumMode::Extended { m: 1 },
    )
    .unwrap();
    let mut rng = Rng::new(4);
    let f = rng.quant_unsigned_vec(8, 2000);
    let g = rng.quant_unsigned_vec(8, dp.k);
    assert_eq!(conv1d_hikonv(&f, &g, &dp), conv1d_ref(&f, &g));
}

/// Surfaces for the three standard multipliers are internally consistent:
/// wider hardware never loses throughput at equal (p, q).
#[test]
fn wider_multipliers_dominate() {
    let dsp = surface(
        Multiplier::DSP48E2,
        Signedness::Unsigned,
        AccumMode::Single,
    );
    let cpu32 = surface(Multiplier::CPU32, Signedness::Unsigned, AccumMode::Single);
    let cpu64 = surface(Multiplier::CPU64, Signedness::Unsigned, AccumMode::Single);
    for p in 1..=8 {
        for q in 1..=8 {
            assert!(cpu32.ops(p, q) >= dsp.ops(p, q), "p={p} q={q}");
            assert!(cpu64.ops(p, q) >= cpu32.ops(p, q), "p={p} q={q}");
        }
    }
}

/// A deep layer exceeding any single guard budget still evaluates exactly
/// through channel blocking (the §III-B M-map accumulation rule).
#[test]
fn deep_channel_layer_via_blocking() {
    let shape = ConvShape {
        ci: 128,
        co: 2,
        hi: 5,
        wi: 9,
        k: 3,
    };
    let mut rng = Rng::new(5);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let eng = Conv2dHiKonv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap();
    assert!(eng.channel_block() >= 1);
    assert_eq!(eng.conv(&input), conv2d_ref(&input, &weights, shape));
}
