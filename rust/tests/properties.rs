//! Whole-crate property tests: the paper's theorems as executable
//! invariants, over randomized multipliers, bitwidths, signedness, shapes.

use hikonv::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use hikonv::conv::reference::{conv2d_ref, ConvShape};
use hikonv::conv::{conv1d_hikonv, conv1d_ref};
use hikonv::packing::{pack_signed, pack_signed_recursive, pack_spec, pack_unsigned};
use hikonv::testing::{assert_seq_eq, check, default_cases};
use hikonv::theory::{solve, solve_all, AccumMode, DesignPoint, Multiplier, Signedness};
use hikonv::util::rng::Rng;

/// Theorem 1 over *random multiplier geometries*: any (Bit_A, Bit_B) in
/// [8, 64]² with any (p, q) produces an exact F_{N,K}.
#[test]
fn prop_theorem1_random_multipliers() {
    check(
        "Thm.1: random multiplier geometry, single block",
        0xA1,
        default_cases(),
        |rng: &mut Rng, _| {
            let bit_a = 8 + rng.below(57) as u32;
            let bit_b = 8 + rng.below(57) as u32;
            let p = 1 + rng.below(bit_a.min(8) as u64) as u32;
            let q = 1 + rng.below(bit_b.min(8) as u64) as u32;
            (bit_a, bit_b, p, q, rng.next_u64())
        },
        |&(bit_a, bit_b, p, q, seed)| {
            let dp = solve(
                Multiplier::new(bit_a, bit_b),
                p,
                q,
                Signedness::Unsigned,
                AccumMode::Single,
            )
            .map_err(|e| e.to_string())?;
            let mut rng = Rng::new(seed);
            let f = rng.quant_unsigned_vec(p, dp.n);
            let g = rng.quant_unsigned_vec(q, dp.k);
            let y = hikonv::conv::conv1d::fnk_block(&f, &g, &dp);
            assert_seq_eq(&y, &conv1d_ref(&f, &g))
        },
    );
}

/// Theorem 2 extension with channel accumulation depth m: guard bits hold
/// for the *worst-case* all-max inputs.
#[test]
fn prop_guard_bits_worst_case() {
    check(
        "guard bits absorb worst-case accumulation",
        0xA2,
        default_cases() / 2,
        |rng: &mut Rng, _| {
            let p = 1 + rng.below(8) as u32;
            let m = 1 + rng.below(16);
            (p, m)
        },
        |&(p, m)| {
            let dp = solve(
                Multiplier::CPU32,
                p,
                p,
                Signedness::Unsigned,
                AccumMode::Extended { m },
            )
            .map_err(|e| e.to_string())?;
            // m parallel worst-case convolutions summed segment-wise must
            // still fit: emulate by conv of all-max values, m-fold.
            let fmax = (1i64 << p) - 1;
            let f = vec![fmax; 64];
            let g = vec![fmax; dp.k];
            let one = conv1d_hikonv(&f, &g, &dp);
            let want = conv1d_ref(&f, &g);
            assert_seq_eq(&one, &want)?;
            // The packed-domain m-fold sum is what conv2d does; covered by
            // prop_theorem3 below. Here assert the bound arithmetic:
            let terms = m * dp.k as u64;
            let max_seg = terms as i128 * (fmax as i128) * (fmax as i128);
            if max_seg >= (1i128 << dp.s) {
                return Err(format!("segment bound violated: {max_seg} >= 2^{}", dp.s));
            }
            Ok(())
        },
    );
}

/// Theorem 3 over random layer shapes *and* random multiplier widths.
#[test]
fn prop_theorem3_random_layers() {
    check(
        "Thm.3: DNN layer == reference over random shapes/multipliers",
        0xA3,
        (default_cases() / 8).max(8),
        |rng: &mut Rng, _| {
            let bit = [24u32, 32, 48][rng.below(3) as usize];
            let k = [1usize, 3][rng.below(2) as usize];
            let shape = ConvShape {
                ci: 1 + rng.below(8) as usize,
                co: 1 + rng.below(3) as usize,
                hi: k + rng.below(4) as usize,
                wi: k + rng.below(10) as usize,
                k,
            };
            let p = 1 + rng.below(4) as u32;
            let q = 1 + rng.below(4) as u32;
            (bit, shape, p, q, rng.next_u64())
        },
        |&(bit, shape, p, q, seed)| {
            let mut rng = Rng::new(seed);
            let input = rng.quant_unsigned_vec(p, shape.input_len());
            let weights = rng.quant_signed_vec(q, shape.weight_len());
            let eng = Conv2dHiKonv::new(
                Conv2dSpec {
                    shape,
                    mult: Multiplier::new(bit, bit),
                    p,
                    q,
                    signedness: Signedness::UnsignedBySigned,
                },
                &weights,
            )?;
            assert_seq_eq(&eng.conv(&input), &conv2d_ref(&input, &weights, shape))
        },
    );
}

/// Eq.-13 signed packing equals the wrapping-sum definition for any slice
/// width and payload.
#[test]
fn prop_signed_packing_equivalence() {
    check(
        "Eq.13 recursion == wrapping sum",
        0xA4,
        default_cases(),
        |rng: &mut Rng, size| {
            let s = 4 + rng.below(13) as u32;
            let n = 1 + rng.below((120 / s as u64).min(size as u64 + 1)) as usize;
            let bits = 1 + rng.below((s - 1).min(8) as u64) as u32;
            (s, rng.quant_signed_vec(bits, n))
        },
        |(s, vals)| {
            if pack_signed_recursive(vals, *s) == pack_signed(vals, *s) {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        },
    );
}

/// Unsigned packing is the wrapping sum, too (Eq. 11 == definition).
#[test]
fn prop_unsigned_packing_is_spec() {
    check(
        "Eq.11 == wrapping sum",
        0xA5,
        default_cases(),
        |rng: &mut Rng, size| {
            let s = 4 + rng.below(13) as u32;
            let n = 1 + rng.below((120 / s as u64).min(size as u64 + 1)) as usize;
            let bits = 1 + rng.below(s.min(8) as u64) as u32;
            (s, rng.quant_unsigned_vec(bits, n))
        },
        |(s, vals)| {
            if pack_unsigned(vals, *s) == pack_spec(vals, *s) {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        },
    );
}

/// Solver invariants: every enumerated point validates; the chosen point
/// maximizes ops; N and K shrink monotonically in S.
#[test]
fn prop_solver_invariants() {
    check(
        "solver soundness + optimality",
        0xA6,
        default_cases(),
        |rng: &mut Rng, _| {
            let bit_a = 8 + rng.below(57) as u32;
            let bit_b = 8 + rng.below(57) as u32;
            let p = 1 + rng.below(bit_a.min(8) as u64) as u32;
            let q = 1 + rng.below(bit_b.min(8) as u64) as u32;
            let signed = rng.below(2) == 1;
            (bit_a, bit_b, p, q, signed)
        },
        |&(bit_a, bit_b, p, q, signed)| {
            let sgn = if signed {
                Signedness::Signed
            } else {
                Signedness::Unsigned
            };
            let mult = Multiplier::new(bit_a, bit_b);
            let all = solve_all(mult, p, q, sgn, AccumMode::Single)
                .map_err(|e| e.to_string())?;
            let best = solve(mult, p, q, sgn, AccumMode::Single)
                .map_err(|e| e.to_string())?;
            let max_ops = all.iter().map(DesignPoint::ops_per_mult).max().unwrap();
            if best.ops_per_mult() != max_ops {
                return Err(format!(
                    "solve() not optimal: {} vs {}",
                    best.ops_per_mult(),
                    max_ops
                ));
            }
            for dp in &all {
                dp.validate()?;
            }
            for w in all.windows(2) {
                if w[1].s > w[0].s && (w[1].n > w[0].n || w[1].k > w[0].k) {
                    return Err("N/K not monotone in S".into());
                }
            }
            Ok(())
        },
    );
}

/// Linearity: conv(f1 + f2, g) == conv(f1, g) + conv(f2, g) — exercised on
/// the packed engine (catches segment-boundary bleed).
#[test]
fn prop_linearity_of_packed_conv() {
    let dp = solve(
        Multiplier::CPU32,
        3,
        3,
        Signedness::Unsigned,
        AccumMode::Extended { m: 1 },
    )
    .unwrap();
    check(
        "packed conv is linear",
        0xA7,
        default_cases() / 2,
        |rng: &mut Rng, size| {
            let len = 1 + rng.below((size as u64 * 4).max(4)) as usize;
            (
                rng.quant_unsigned_vec(2, len), // halves so the sum stays 3-bit
                rng.quant_unsigned_vec(2, len),
                rng.quant_unsigned_vec(3, dp.k),
            )
        },
        |(f1, f2, g)| {
            let sum: Vec<i64> = f1.iter().zip(f2).map(|(a, b)| a + b).collect();
            let lhs = conv1d_hikonv(&sum, g, &dp);
            let a = conv1d_hikonv(f1, g, &dp);
            let b = conv1d_hikonv(f2, g, &dp);
            let rhs: Vec<i64> = a.iter().zip(&b).map(|(x, y)| x + y).collect();
            assert_seq_eq(&lhs, &rhs)
        },
    );
}
