//! End-to-end serving pipeline tests: source → batcher → inference →
//! metrics, on CPU engines and (when artifacts exist) the PJRT backend.

use hikonv::coordinator::pipeline::{CpuBackend, PjrtBackend};
use hikonv::coordinator::{serve, ServeConfig};
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::runtime::{artifacts, artifacts_dir, Runtime};
use hikonv::theory::Multiplier;
use std::time::Duration;

fn config(frames: u64) -> ServeConfig {
    ServeConfig {
        frames,
        source_fps_cap: None,
        queue_depth: 4,
        max_batch: 2,
        linger: Duration::from_millis(1),
        seed: 11,
        bits: 4,
        ..ServeConfig::default()
    }
}

#[test]
fn cpu_hikonv_pipeline_end_to_end() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 11);
    let runner = CpuRunner::new(model, weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
    let report = serve(Box::new(CpuBackend::new(runner)), &config(8)).unwrap();
    assert_eq!(report.frames, 8);
    assert!(report.fps > 0.0);
    assert_eq!(report.latency.count(), 8);
    assert!(report.mean_batch >= 1.0);
    assert!(report.slo.accounted());
    assert_eq!(report.slo.completed, 8);
}

#[test]
fn baseline_and_hikonv_backends_detect_identically() {
    // Same seed => same synthetic frames => identical detections expected
    // because the engines are bit-exact equivalents.
    let model = ultranet_tiny();
    let weights = random_weights(&model, 13);
    let base = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
    let hik =
        CpuRunner::new(model.clone(), weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
    let (c, h, w) = model.input;
    let mut rng = hikonv::util::rng::Rng::new(17);
    for _ in 0..3 {
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = base.infer(&frame);
        let b = hik.infer(&frame);
        assert_eq!(base.decode(&a), hik.decode(&b));
    }
}

#[test]
fn feeder_cap_reproduces_arm_bottleneck_shape() {
    // With a feeder cap far below the backend's speed, throughput pins to
    // the cap — the Table-II "measured 401 fps" situation.
    struct Fast;
    impl hikonv::coordinator::InferBackend for Fast {
        fn name(&self) -> &str {
            "fast"
        }
        fn input_dims(&self) -> (usize, usize, usize) {
            (1, 2, 2)
        }
        fn infer_batch(
            &mut self,
            frames: &[hikonv::coordinator::Frame],
        ) -> Vec<hikonv::coordinator::pipeline::Detection> {
            frames
                .iter()
                .map(|f| hikonv::coordinator::pipeline::Detection {
                    frame_id: f.id,
                    cell: (0, 0),
                })
                .collect()
        }
    }
    let mut cfg = config(60);
    cfg.source_fps_cap = Some(300.0);
    let capped = serve(Box::new(Fast), &cfg).unwrap();
    cfg.source_fps_cap = None;
    let uncapped = serve(Box::new(Fast), &cfg).unwrap();
    assert!(
        capped.fps < uncapped.fps / 3.0,
        "cap {:.0} vs uncapped {:.0}",
        capped.fps,
        uncapped.fps
    );
    // Upper bound only: goodput can't beat the feeder cap by more than
    // scheduling slack. (A hard lower bound was wall-clock flaky on slow
    // runners; the relative assertion above already pins the shape.)
    assert!(capped.fps < 400.0, "{}", capped.fps);
}

#[test]
fn pjrt_backend_pipeline_end_to_end() {
    if !artifacts_dir().join(artifacts::ULTRANET_TINY).exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let rt = Runtime::cpu().unwrap();
    let loaded = rt.load_artifact(artifacts::ULTRANET_TINY).unwrap();
    let model = ultranet_tiny();
    let backend = PjrtBackend::new(loaded, model.input, model.output_dims());
    let report = serve(Box::new(backend), &config(6)).unwrap();
    assert_eq!(report.frames, 6);
    assert_eq!(report.backend, "pjrt-ultranet");
    // Determinism: running again with the same seed yields the same count
    // and a comparable latency profile.
    let rt2 = Runtime::cpu().unwrap();
    let loaded2 = rt2.load_artifact(artifacts::ULTRANET_TINY).unwrap();
    let backend2 = PjrtBackend::new(loaded2, model.input, model.output_dims());
    let report2 = serve(Box::new(backend2), &config(6)).unwrap();
    assert_eq!(report2.frames, 6);
}

/// A deliberately slow backend for overload/deadline tests.
struct Slow {
    per_batch: Duration,
}
impl hikonv::coordinator::InferBackend for Slow {
    fn name(&self) -> &str {
        "slow"
    }
    fn input_dims(&self) -> (usize, usize, usize) {
        (1, 2, 2)
    }
    fn infer_batch(
        &mut self,
        frames: &[hikonv::coordinator::Frame],
    ) -> Vec<hikonv::coordinator::pipeline::Detection> {
        std::thread::sleep(self.per_batch);
        frames
            .iter()
            .map(|f| hikonv::coordinator::pipeline::Detection {
                frame_id: f.id,
                cell: (0, 0),
            })
            .collect()
    }
}

#[test]
fn deadline_expiry_sheds_queued_frames_pre_inference() {
    let mut cfg = config(12);
    cfg.deadline = Some(Duration::from_millis(1));
    let report = serve(
        Box::new(Slow {
            per_batch: Duration::from_millis(25),
        }),
        &cfg,
    )
    .unwrap();
    // Frames stuck behind the slow backend blow their 1ms budget and are
    // shed by the batcher before inference, not after.
    assert!(report.slo.expired > 0, "expected expiries, got {:?}", report.slo);
    assert!(report.slo.accounted());
    assert_eq!(report.slo.admitted, 12);
}

#[test]
fn shed_policy_keeps_pipeline_live_under_overload() {
    let mut cfg = config(40);
    cfg.policy = hikonv::coordinator::AdmissionPolicy::Shed;
    cfg.queue_depth = 2;
    let report = serve(
        Box::new(Slow {
            per_batch: Duration::from_millis(10),
        }),
        &cfg,
    )
    .unwrap();
    // An uncapped feeder against a 10ms/batch backend is heavy overload:
    // the bounded queue must shed rather than grow, and every offered
    // frame must still be accounted for.
    assert!(report.slo.shed > 0, "expected shedding, got {:?}", report.slo);
    assert!(report.slo.completed > 0);
    assert!(report.slo.accounted());
    assert_eq!(report.slo.admitted, 40);
}
