//! Acceptance suite for the static packing-soundness verifier
//! (`hikonv::analysis`).
//!
//! * **Property grid + oracle** — every solved design point over a
//!   `(multiplier, p, q, signedness, accumulation)` grid, plus tampered
//!   variants (undersized slice, inflated operand counts, deepened
//!   accumulation), is checked against an independent i128 brute-force
//!   oracle that enumerates every concrete operand value and simulates
//!   adversarial all-max-magnitude accumulation. Soundness: the verifier
//!   never accepts a point the oracle overflows. Tightness: the verifier
//!   accepts every point the solver produces.
//! * **Executable cross-check** — every accepted point is run through
//!   the real 1-D HiKonv engine on adversarial extreme-value inputs and
//!   must be bit-identical to the reference convolution.
//! * **Integration points** — deliberately corrupted plans are rejected
//!   at all three integration layers (CLI-level `verify_plan`, the
//!   planner's mandatory cross-check, artifact load) with distinct
//!   machine-readable `V-*` codes.

use hikonv::analysis::{assumed_operands, check_design, verify_graph, verify_plan, Code, Evidence};
use hikonv::artifact::Artifact;
use hikonv::conv::{conv1d_hikonv, conv1d_ref};
use hikonv::engine::{EngineConfig, EnginePlan};
use hikonv::models::{random_graph_weights, zoo};
use hikonv::theory::{solve, AccumMode, DesignPoint, Multiplier, Signedness};

const SIGNEDNESSES: [Signedness; 3] = [
    Signedness::Unsigned,
    Signedness::Signed,
    Signedness::UnsignedBySigned,
];

/// Every concrete level of a `bits`-wide operand — restated from the
/// paper's conventions, independent of the verifier's interval code.
fn levels(bits: u32, signed: bool) -> Vec<i128> {
    if signed {
        let half = 1i128 << (bits - 1);
        (-half..half).collect()
    } else {
        (0..(1i128 << bits)).collect()
    }
}

/// `(feature levels, kernel levels)` under the design's convention.
fn operand_levels(dp: &DesignPoint) -> (Vec<i128>, Vec<i128>) {
    match dp.signedness {
        Signedness::Unsigned => (levels(dp.p, false), levels(dp.q, false)),
        Signedness::Signed => (levels(dp.p, true), levels(dp.q, true)),
        Signedness::UnsignedBySigned => (levels(dp.p, false), levels(dp.q, true)),
    }
}

/// Does `[lo, hi]` fit an `s`-bit slice (unsigned when non-negative,
/// two's-complement otherwise)?
fn fits_slice(lo: i128, hi: i128, s: u32) -> bool {
    if s == 0 {
        return false;
    }
    if s >= 126 {
        return true;
    }
    if lo >= 0 {
        hi < (1i128 << s)
    } else {
        lo >= -(1i128 << (s - 1)) && hi < (1i128 << (s - 1))
    }
}

/// The brute-force oracle: enumerate every concrete product of the
/// design's operand ranges, push `terms` adversarially same-signed
/// copies of the worst one through a segment, and check the slice,
/// the Eq. 7/8 port layouts, and the 128-bit widest software lane.
fn oracle_accepts(dp: &DesignPoint, terms: u64) -> bool {
    if dp.n == 0 || dp.k == 0 || dp.s == 0 {
        return false;
    }
    if dp.p + (dp.n as u32 - 1) * dp.s > dp.mult.bit_a {
        return false;
    }
    if dp.q + (dp.k as u32 - 1) * dp.s > dp.mult.bit_b {
        return false;
    }
    if dp.s as u128 * (dp.n + dp.k - 1) as u128 + 1 > 128 {
        return false;
    }
    let (fl, gl) = operand_levels(dp);
    let mut max_prod = i128::MIN;
    let mut min_prod = i128::MAX;
    for &a in &fl {
        for &b in &gl {
            max_prod = max_prod.max(a * b);
            min_prod = min_prod.min(a * b);
        }
    }
    let t = terms as i128;
    let hi = max_prod.max(0).saturating_mul(t);
    let lo = min_prod.min(0).saturating_mul(t);
    fits_slice(lo, hi, dp.s)
}

/// The verifier's verdict on a raw design point under its own assumed
/// operand convention.
fn verifier_accepts(dp: &DesignPoint, terms: u64) -> bool {
    let (f, g) = assumed_operands(dp.p, dp.q, dp.signedness);
    check_design(dp, f, g, terms, "grid").1.is_empty()
}

/// Corruptions of a solved point: undersized slice, inflated packing
/// counts (breaking the Eq. 7/8 port layouts or the lane), deepened
/// accumulation.
fn tampered(dp: &DesignPoint) -> Vec<DesignPoint> {
    let mut out = Vec::new();
    if dp.s > 1 {
        let mut t = *dp;
        t.s -= 1;
        t.gb = t.gb.saturating_sub(1);
        out.push(t);
    }
    let mut wide_n = *dp;
    wide_n.n += 1;
    out.push(wide_n);
    let mut wide_k = *dp;
    wide_k.k += 1;
    out.push(wide_k);
    let mut deep = *dp;
    deep.accum = AccumMode::Extended { m: 64 };
    out.push(deep);
    out
}

#[test]
fn grid_soundness_and_tightness_against_the_brute_force_oracle() {
    let mults = [Multiplier::CPU32, Multiplier::CPU64, Multiplier::DSP48E2];
    let mut solved = 0usize;
    let mut caught = 0usize;
    for mult in mults {
        for p in 1..=6u32 {
            for q in 1..=6u32 {
                for sg in SIGNEDNESSES {
                    for m in [1u64, 3] {
                        let Ok(dp) = solve(mult, p, q, sg, AccumMode::Extended { m }) else {
                            continue;
                        };
                        let terms = dp.accum.terms(dp.n, dp.k);
                        // Tightness: solver output is accepted by both.
                        assert!(oracle_accepts(&dp, terms), "oracle rejects solved {dp:?}");
                        assert!(verifier_accepts(&dp, terms), "verifier rejects solved {dp:?}");
                        solved += 1;
                        // Soundness: every tampered variant the oracle
                        // overflows must also fail the interval proof.
                        for t in tampered(&dp) {
                            let tt = t.accum.terms(t.n, t.k);
                            if !oracle_accepts(&t, tt) {
                                assert!(
                                    !verifier_accepts(&t, tt),
                                    "verifier accepted an oracle-overflowing point: {t:?}"
                                );
                                caught += 1;
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(solved >= 100, "grid too sparse: only {solved} solved points");
    assert!(caught >= 100, "tampering never overflowed: only {caught} caught");
}

#[test]
fn undersized_guard_bits_are_a_v_guard() {
    let dp = solve(
        Multiplier::CPU32,
        4,
        4,
        Signedness::UnsignedBySigned,
        AccumMode::Extended { m: 2 },
    )
    .unwrap();
    let mut bad = dp;
    bad.s -= 1;
    bad.gb = bad.gb.saturating_sub(1);
    let terms = bad.accum.terms(bad.n, bad.k);
    let (f, g) = assumed_operands(bad.p, bad.q, bad.signedness);
    let (_, diags) = check_design(&bad, f, g, terms, "t");
    assert!(
        diags.iter().any(|d| d.code == Code::Guard),
        "expected V-GUARD, got: {diags:?}"
    );
}

/// Adversarial all-max-magnitude operand vectors for the executable
/// engine: unsigned ranges saturate high, signed ranges alternate
/// between their two extremes.
fn adversarial(bits: u32, signed: bool, len: usize) -> Vec<i64> {
    if signed {
        let half = 1i64 << (bits - 1);
        (0..len).map(|i| if i % 2 == 0 { -half } else { half - 1 }).collect()
    } else {
        vec![(1i64 << bits) - 1; len]
    }
}

#[test]
fn accepted_points_run_bit_exact_on_adversarial_inputs() {
    for mult in [Multiplier::CPU32, Multiplier::CPU64] {
        for p in 1..=4u32 {
            for q in 1..=4u32 {
                for sg in SIGNEDNESSES {
                    let Ok(dp) = solve(mult, p, q, sg, AccumMode::Extended { m: 1 }) else {
                        continue;
                    };
                    let terms = dp.accum.terms(dp.n, dp.k);
                    assert!(verifier_accepts(&dp, terms), "{dp:?}");
                    let (f_signed, g_signed) = match sg {
                        Signedness::Unsigned => (false, false),
                        Signedness::Signed => (true, true),
                        Signedness::UnsignedBySigned => (false, true),
                    };
                    let f = adversarial(p, f_signed, 8 * dp.n.max(1));
                    let g = adversarial(q, g_signed, 2 * dp.k + 1);
                    assert_eq!(
                        conv1d_hikonv(&f, &g, &dp),
                        conv1d_ref(&f, &g),
                        "accepted point is not bit-exact: {dp:?}"
                    );
                }
            }
        }
    }
}

#[test]
fn every_zoo_workload_passes_cli_level_verification() {
    for name in zoo::NAMES {
        let graph = zoo::build(name).unwrap();
        let report = verify_graph(&graph, &EngineConfig::auto().with_threads(2)).unwrap();
        assert!(
            report.is_sound(),
            "{name}: {}",
            report.render_diagnostics()
        );
    }
}

#[test]
fn corruption_is_rejected_at_all_three_integration_points_with_distinct_codes() {
    let cfg = EngineConfig::auto().with_threads(2);
    let graph = zoo::build("fc-head").unwrap();
    let weights = random_graph_weights(&graph, 0xA07).unwrap();

    // (1) CLI-level `verify`: a doctored plan row is a V-PLAN.
    let mut plan = EnginePlan::plan_graph(&graph, &cfg).unwrap();
    plan.layers[0].ops_per_mult += 5;
    let report = verify_plan(&graph, &plan, &Evidence::none()).unwrap();
    assert!(!report.is_sound());
    assert!(
        report.diagnostics().iter().any(|d| d.code == Code::Plan),
        "{}",
        report.render_diagnostics()
    );

    // (2) planner cross-check: a bit override narrower than the graph's
    // levels passes the solver's formula feasibility but fails the
    // interval proof, so `plan_graph` itself refuses with a V-RANGE —
    // while the unverified entry point still produces a plan.
    let narrow = cfg.clone().with_bits(2, 2);
    assert!(EnginePlan::plan_graph_unverified(&graph, &narrow).is_ok());
    let err = EnginePlan::plan_graph(&graph, &narrow)
        .expect_err("cross-check must reject the narrowed override");
    assert!(err.contains("V-RANGE"), "{err}");
    assert!(err.contains("interval proof"), "{err}");

    // (3) artifact load: a hand-edited requant shift in an otherwise
    // checksum-clean file is a V-REQUANT at `into_runner` time.
    let mut art = Artifact::compile(graph, weights, cfg).unwrap();
    assert!(!art.shifts.is_empty());
    art.shifts[0] += 7;
    let err = Artifact::from_bytes(&art.to_bytes())
        .unwrap()
        .into_runner()
        .expect_err("tampered shift must be rejected at load")
        .to_string();
    assert!(err.contains("V-REQUANT"), "{err}");
}

#[test]
fn lane_overflow_is_a_v_lane_under_a_narrow_configured_lane() {
    let graph = zoo::build("fc-head").unwrap();
    // Force the hikonv kernel so `auto` cannot sidestep the narrow lane
    // by planning the baseline everywhere.
    let cfg = EngineConfig::named("hikonv").with_threads(2).with_lane_bits(16);
    let report = verify_graph(&graph, &cfg).unwrap();
    assert!(!report.is_sound());
    assert!(
        report.diagnostics().iter().any(|d| d.code == Code::Lane),
        "{}",
        report.render_diagnostics()
    );
}
