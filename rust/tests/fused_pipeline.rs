//! Fused-pipeline integration tests: the arena-based `CpuRunner::infer`
//! must be bit-exact vs the seed per-layer path (`infer_unfused`) for
//! every engine kind × thread count, `infer_batch` must equal N single
//! inferences, and arena reuse must be deterministic across frames.

use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_weights, CpuRunner, EngineKind};
use hikonv::testing::assert_seq_eq;
use hikonv::theory::Multiplier;
use hikonv::util::rng::Rng;

fn every_engine_kind() -> Vec<EngineKind> {
    let m = Multiplier::CPU32;
    vec![
        EngineKind::Baseline,
        EngineKind::HiKonv(m),
        EngineKind::HiKonvTiled(m, 1),
        EngineKind::HiKonvTiled(m, 2),
        EngineKind::HiKonvTiled(m, 4),
        EngineKind::Im2Row(m, 1),
        EngineKind::Im2Row(m, 2),
        EngineKind::Im2Row(m, 4),
    ]
}

#[test]
fn fused_is_bit_exact_vs_seed_for_every_kind_and_thread_count() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 301);
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xF05E);
    let frames: Vec<Vec<i64>> = (0..2).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
    // The seed path on the baseline engine is the ground truth.
    let oracle = CpuRunner::new(model.clone(), weights.clone(), EngineKind::Baseline).unwrap();
    let truths: Vec<Vec<i64>> = frames.iter().map(|f| oracle.infer_unfused(f)).collect();
    for kind in every_engine_kind() {
        let r = CpuRunner::new(model.clone(), weights.clone(), kind).unwrap();
        for (f, truth) in frames.iter().zip(&truths) {
            let fused = r.infer(f);
            assert_seq_eq(&fused, truth).unwrap();
            assert_seq_eq(&fused, &r.infer_unfused(f)).unwrap();
        }
    }
}

#[test]
fn infer_into_reuses_the_head_buffer() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 302);
    let r = CpuRunner::new(model.clone(), weights, EngineKind::HiKonv(Multiplier::CPU32)).unwrap();
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xF060);
    let mut out = vec![42i64; r.head_len()];
    for _ in 0..3 {
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        r.infer_into(&frame, &mut out);
        assert_seq_eq(&out, &r.infer(&frame)).unwrap();
    }
}

#[test]
fn infer_batch_is_identical_to_n_single_infers() {
    let model = ultranet_tiny();
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xF061);
    let frames: Vec<Vec<i64>> = (0..6).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
    let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
    for kind in [
        // Pooled kinds exercise frame-level parallelism; serial kinds the
        // fallback loop. All must match per-frame infer exactly.
        EngineKind::HiKonvTiled(Multiplier::CPU32, 3),
        EngineKind::Im2Row(Multiplier::CPU32, 2),
        EngineKind::HiKonv(Multiplier::CPU32),
        EngineKind::Baseline,
    ] {
        let weights = random_weights(&model, 303);
        let r = CpuRunner::new(model.clone(), weights, kind).unwrap();
        let batched = r.infer_batch(&refs);
        assert_eq!(batched.len(), frames.len(), "{kind:?}");
        for (f, b) in frames.iter().zip(&batched) {
            assert_seq_eq(b, &r.infer(f)).unwrap();
        }
    }
}

#[test]
fn infer_batch_edge_sizes() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 304);
    let r = CpuRunner::new(
        model.clone(),
        weights,
        EngineKind::HiKonvTiled(Multiplier::CPU32, 4),
    )
    .unwrap();
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xF062);
    // Empty batch, single frame, and a batch larger than the pool.
    assert!(r.infer_batch(&[]).is_empty());
    let one = rng.quant_unsigned_vec(4, c * h * w);
    assert_seq_eq(&r.infer_batch(&[one.as_slice()])[0], &r.infer(&one)).unwrap();
    let many: Vec<Vec<i64>> = (0..9).map(|_| rng.quant_unsigned_vec(4, c * h * w)).collect();
    let refs: Vec<&[i64]> = many.iter().map(|f| f.as_slice()).collect();
    for (f, b) in many.iter().zip(&r.infer_batch(&refs)) {
        assert_seq_eq(b, &r.infer(f)).unwrap();
    }
}

#[test]
fn arena_reuse_is_deterministic_across_repeated_frames() {
    let model = ultranet_tiny();
    let weights = random_weights(&model, 305);
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0xF063);
    let a = rng.quant_unsigned_vec(4, c * h * w);
    let b = rng.quant_unsigned_vec(4, c * h * w);
    for kind in [
        EngineKind::HiKonv(Multiplier::CPU32),
        EngineKind::Im2Row(Multiplier::CPU32, 1),
    ] {
        let r = CpuRunner::new(model.clone(), weights.clone(), kind).unwrap();
        // Same frame repeatedly: identical outputs (no state bleed).
        let first = r.infer(&a);
        for _ in 0..3 {
            assert_seq_eq(&r.infer(&a), &first).unwrap();
        }
        // Interleaving a different frame must not perturb the original:
        // the arena (padded borders, packed words, accumulator) is fully
        // rewritten or never read stale.
        let bb = r.infer(&b);
        assert_seq_eq(&r.infer(&a), &first).unwrap();
        assert_seq_eq(&r.infer(&b), &bb).unwrap();
    }
}
