//! Graph pipeline acceptance suite: every built-in graph workload
//! (strided downsampling, FC head, residual block, mixed bitwidths, and
//! the all-features combo) runs bit-exact against the strided-reference
//! oracle under **every** registered kernel and the `auto` planner;
//! plans are deterministic and genuinely per-op; and the `ModelSpec`
//! shim keeps UltraNet bit-exact with its pre-redesign fused pipeline.

use hikonv::engine::{EngineConfig, EnginePlan};
use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{random_graph_weights, random_weights, zoo};
use hikonv::models::{CpuRunner, GraphRunner, GraphSpec};
use hikonv::testing::assert_seq_eq;
use hikonv::util::rng::Rng;

fn workloads() -> Vec<GraphSpec> {
    let mut v: Vec<GraphSpec> = ["strided", "fc-head", "residual", "mixed"]
        .iter()
        .map(|n| zoo::build(n).unwrap())
        .collect();
    v.push(zoo::combo());
    v
}

fn engine_matrix() -> Vec<EngineConfig> {
    vec![
        EngineConfig::named("baseline"),
        EngineConfig::named("hikonv"),
        EngineConfig::named("hikonv-tiled").with_threads(2),
        EngineConfig::named("im2row").with_threads(2),
        EngineConfig::auto().with_threads(2),
    ]
}

#[test]
fn every_workload_is_bit_exact_under_every_registered_kernel() {
    for graph in workloads() {
        let weights = random_graph_weights(&graph, 0xACCE).unwrap();
        let (c, h, w) = graph.input;
        let mut rng = Rng::new(0x6E0 ^ graph.nodes.len() as u64);
        let frames: Vec<Vec<i64>> = (0..2)
            .map(|_| rng.quant_unsigned_vec(graph.input_bits, c * h * w))
            .collect();
        let mut truths: Vec<Option<Vec<i64>>> = vec![None; frames.len()];
        for config in engine_matrix() {
            let label = config.to_string();
            let r = GraphRunner::new(graph.clone(), weights.clone(), config)
                .unwrap_or_else(|e| panic!("{}/{label}: {e}", graph.name));
            for (fi, frame) in frames.iter().enumerate() {
                let fused = r.infer(frame);
                // The kernel-independent strided-reference oracle is the
                // ground truth for every engine...
                let oracle = r.infer_oracle(frame);
                assert_seq_eq(&fused, &oracle)
                    .unwrap_or_else(|e| panic!("{}/{label} vs oracle: {e}", graph.name));
                // ...the node-walk through the bound kernels agrees...
                assert_seq_eq(&fused, &r.infer_unfused(frame))
                    .unwrap_or_else(|e| panic!("{}/{label} vs unfused: {e}", graph.name));
                // ...and every engine agrees with every other engine.
                let existing = truths[fi].clone();
                match existing {
                    Some(t) => assert_seq_eq(&fused, &t)
                        .unwrap_or_else(|e| panic!("{}/{label} cross-engine: {e}", graph.name)),
                    None => truths[fi] = Some(fused),
                }
            }
        }
    }
}

#[test]
fn batched_graph_inference_matches_per_frame() {
    for graph in workloads() {
        let weights = random_graph_weights(&graph, 0xBA7).unwrap();
        let r = GraphRunner::new(
            graph.clone(),
            weights,
            EngineConfig::auto().with_threads(3),
        )
        .unwrap();
        let (c, h, w) = graph.input;
        let mut rng = Rng::new(0xBA8);
        let frames: Vec<Vec<i64>> = (0..4)
            .map(|_| rng.quant_unsigned_vec(graph.input_bits, c * h * w))
            .collect();
        let refs: Vec<&[i64]> = frames.iter().map(|f| f.as_slice()).collect();
        for (f, b) in frames.iter().zip(&r.infer_batch(&refs)) {
            assert_seq_eq(b, &r.infer(f)).unwrap_or_else(|e| panic!("{}: {e}", graph.name));
        }
    }
}

#[test]
fn graph_plans_are_deterministic_and_inspectable() {
    for graph in workloads() {
        let cfg = EngineConfig::auto().with_threads(2);
        let first = EnginePlan::plan_graph(&graph, &cfg).unwrap();
        let info = graph.validate().unwrap();
        assert_eq!(first.layers.len(), info.units.len(), "{}", graph.name);
        for _ in 0..3 {
            let again = EnginePlan::plan_graph(&graph, &cfg).unwrap();
            assert_eq!(again.kernel_names(), first.kernel_names(), "{}", graph.name);
            assert_eq!(again.summary(), first.summary(), "{}", graph.name);
        }
        // The rendered table names every op.
        let rendered = first.render();
        for u in &info.units {
            assert!(rendered.contains(&u.name), "{}: {rendered}", graph.name);
        }
    }
}

#[test]
fn mixed_bitwidth_plans_are_heterogeneous_per_op() {
    let graph = zoo::build("mixed").unwrap();
    let plan = EnginePlan::plan_graph(&graph, &EngineConfig::auto().with_threads(1)).unwrap();
    // Per-op operand bitwidths flow into the plan...
    let bits: Vec<(u32, u32)> = plan.layers.iter().map(|lp| (lp.p, lp.q)).collect();
    assert_eq!(bits[0], (8, 8), "{bits:?}");
    assert_eq!(bits[3], (3, 3), "{bits:?}");
    // ...and narrower ops pack strictly more equivalent ops per wide
    // multiplication (the paper's central bitwidth-throughput tradeoff).
    assert!(
        plan.layers[3].ops_per_mult > plan.layers[0].ops_per_mult,
        "{:?}",
        plan.layers
    );
}

#[test]
fn ultranet_shim_stays_bit_exact_with_the_legacy_pipeline() {
    // The ModelSpec shim and a hand-built equivalent GraphSpec must be
    // the same machine: identical plans, identical outputs, and the
    // fused path still equals the seed-style unfused walk.
    let model = ultranet_tiny();
    let weights = random_weights(&model, 0x5EED);
    let graph: GraphSpec = model.clone().into();
    let gweights = random_graph_weights(&graph, 0x5EED).unwrap();
    let shim = CpuRunner::new(model.clone(), weights, EngineConfig::named("hikonv")).unwrap();
    let direct = GraphRunner::new(graph, gweights, EngineConfig::named("hikonv")).unwrap();
    // Same synthetic weights stream -> same calibration.
    assert_eq!(shim.requant_shifts(), direct.requant_shifts());
    let (c, h, w) = model.input;
    let mut rng = Rng::new(0x5EEE);
    for _ in 0..2 {
        let frame = rng.quant_unsigned_vec(4, c * h * w);
        let a = shim.infer(&frame);
        assert_seq_eq(&a, &direct.infer(&frame)).unwrap();
        assert_seq_eq(&a, &shim.infer_unfused(&frame)).unwrap();
        assert_seq_eq(&a, &direct.infer_oracle(&frame)).unwrap();
        assert_eq!(shim.decode(&a), direct.decode(&a));
    }
}
