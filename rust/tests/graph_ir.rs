//! Graph-IR validation suite: edge typing, degenerate-shape rejection
//! (the `usize`-underflow class), the `ModelSpec` → `GraphSpec` shim,
//! and the `QTensor` typed-activation contracts (widen-into semantics,
//! quantize→dequantize round-trip bounds) across the bitwidth grid.

use hikonv::models::ultranet::ultranet_tiny;
use hikonv::models::{ConvLayer, GraphSpec, LayerOp, ModelSpec};
use hikonv::quant::{QTensor, Quantizer, Shape};
use hikonv::testing::check;
use hikonv::util::rng::Rng;

#[test]
fn degenerate_kernels_error_instead_of_underflowing() {
    // Graph API: k > hi + 2*pad is a validation error with context.
    let g = GraphSpec::new("bad", (1, 3, 3), 4).conv("huge", 2, 9, 1, 1, 4);
    let err = g.validate().unwrap_err().to_string();
    assert!(err.contains("k > hi + 2*pad"), "{err}");
    assert!(err.contains("huge"), "{err}");
    // Legacy API: validation catches it too (conv_out saturates, never
    // wraps, so even pre-validation shape math cannot panic).
    let l = ConvLayer {
        name: "huge".into(),
        ci: 1,
        co: 2,
        hi: 3,
        wi: 3,
        k: 9,
        pad: 1,
        pool_after: false,
        a_bits: 4,
        w_bits: 4,
    };
    assert_eq!(l.conv_out(), (0, 0));
    let m = ModelSpec {
        name: "bad".into(),
        input: (1, 3, 3),
        layers: vec![l],
    };
    let err = m.validate().unwrap_err();
    assert!(err.contains("k > hi + 2*pad"), "{err}");
}

#[test]
fn graph_validation_rejects_inconsistent_structures() {
    // Conv directly on an accumulator edge.
    let g = GraphSpec::new("g", (2, 8, 8), 4)
        .conv("a", 2, 3, 1, 1, 4)
        .conv("b", 2, 3, 1, 1, 4);
    assert!(g.validate().is_err());
    // Residual add against mismatched dims.
    let g = GraphSpec::new("g", (2, 8, 8), 4)
        .conv("a", 2, 3, 1, 1, 4)
        .requant(4)
        .maxpool(2)
        .add(1);
    assert!(g.validate().is_err());
    // Forward (non-earlier) residual reference.
    let g = GraphSpec::new("g", (2, 8, 8), 4)
        .conv("a", 2, 3, 1, 1, 4)
        .requant(4)
        .add(5);
    assert!(g.validate().is_err());
    // Out-of-range bitwidths.
    let g = GraphSpec::new("g", (2, 8, 8), 4).conv("a", 2, 3, 1, 1, 9);
    assert!(g.validate().is_err());
    let g = GraphSpec::new("g", (2, 8, 8), 4)
        .conv("a", 2, 3, 1, 1, 4)
        .requant(0);
    assert!(g.validate().is_err());
    // Pool window larger than the map.
    let g = GraphSpec::new("g", (2, 4, 4), 4).maxpool(5);
    assert!(g.validate().is_err());
    // Stride 0.
    let g = GraphSpec::new("g", (2, 8, 8), 4).conv("a", 2, 3, 0, 1, 4);
    assert!(g.validate().is_err());
    // Empty graph.
    assert!(GraphSpec::new("empty", (1, 1, 1), 4).validate().is_err());
}

#[test]
fn modelspec_shim_lowers_every_layer_faithfully() {
    let model = ultranet_tiny();
    let g: GraphSpec = model.clone().into();
    let info = g.validate().unwrap();
    assert_eq!(info.units.len(), model.layers.len());
    assert_eq!(info.output_dims(), model.output_dims());
    // The node chain is Conv [Requant [MaxPool]] ... Conv (head raw).
    let mut requants = 0;
    let mut pools = 0;
    for node in &g.nodes {
        match node.op {
            LayerOp::Requant { bits } => {
                requants += 1;
                assert_eq!(bits, 4);
            }
            LayerOp::MaxPool { k } => {
                pools += 1;
                assert_eq!(k, 2);
            }
            LayerOp::Conv2d { stride, .. } => assert_eq!(stride, 1),
            ref other => panic!("unexpected op {other:?}"),
        }
    }
    assert_eq!(requants, model.layers.len() - 1);
    assert_eq!(
        pools,
        model.layers.iter().filter(|l| l.pool_after).count()
    );
}

#[test]
fn edge_types_flow_through_the_graph() {
    let g = GraphSpec::new("typed", (3, 8, 8), 4)
        .conv("c1", 4, 3, 1, 1, 4)
        .relu()
        .requant(5)
        .avgpool(2)
        .fc("head", 7, 4);
    let info = g.validate().unwrap();
    // Conv output is a wide signed accumulator edge...
    assert!(info.nodes[0].ty.signed);
    assert!(!info.nodes[0].ty.is_narrow());
    // ...relu drops the sign, requant narrows to 5 unsigned bits...
    assert!(!info.nodes[1].ty.signed);
    assert_eq!(info.nodes[2].ty.bits, 5);
    assert_eq!(info.nodes[2].ty.level_range(), (0, 31));
    // ...avgpool preserves the type, and the FC widens again.
    assert_eq!(info.nodes[3].ty.bits, 5);
    assert_eq!(info.nodes[3].dims, (4, 4, 4));
    assert!(!info.nodes[4].ty.is_narrow());
    assert_eq!(info.output_dims(), (7, 1, 1));
}

#[test]
fn qtensor_roundtrip_error_is_bounded_across_the_grid() {
    // quantize -> dequantize must stay within half a scale step, for
    // every bitwidth and signedness.
    for bits in 1..=8u32 {
        for signed in [false, true] {
            if bits == 1 && signed {
                // 1-bit signed levels are {-1, 0}: the positive range is
                // empty, so a symmetric fit has no finite scale.
                continue;
            }
            check(
                "qtensor-roundtrip",
                0x9_0000 + bits as u64 * 2 + signed as u64,
                64,
                |rng, size| {
                    (0..size.max(1))
                        .map(|_| (rng.f64() as f32 - if signed { 0.5 } else { 0.0 }) * 20.0)
                        .collect::<Vec<f32>>()
                },
                |vals| {
                    let q = Quantizer::fit(vals, bits, signed);
                    let t = q.quantize(vals, Shape(vec![vals.len()]));
                    assert_eq!(t.bits, bits);
                    assert_eq!(t.signed, signed);
                    let rec = t.dequantize();
                    for (&v, &r) in vals.iter().zip(&rec) {
                        let v = if signed { v } else { v.max(0.0) };
                        if (r - v).abs() > q.scale / 2.0 + 1e-5 {
                            return Err(format!(
                                "bits={bits} signed={signed}: v={v} rec={r} scale={}",
                                q.scale
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn widen_into_is_the_borrowed_twin_of_to_i64() {
    let mut rng = Rng::new(0x81D);
    for bits in 1..=8u32 {
        let levels = rng.quant_signed_vec(bits, 37);
        let t = QTensor::from_levels(Shape(vec![37]), &levels, bits, true, 0.25).unwrap();
        let mut buf = vec![-1i64; 37];
        t.widen_into(&mut buf);
        assert_eq!(buf, t.to_i64());
        assert_eq!(buf, levels);
    }
}
