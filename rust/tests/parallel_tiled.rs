//! Properties of the parallel tiled execution subsystem: the packed dot
//! product and the tiled conv2d engine match the scalar references over
//! the full `(p, q) ∈ 1..=8` bitwidth grid and every signedness, and the
//! tiled outputs are bit-identical for any thread count.

use hikonv::conv::conv2d::{Conv2dHiKonv, Conv2dSpec};
use hikonv::conv::dot::{dot_ref, DotHiKonv};
use hikonv::conv::im2row::Im2RowConv;
use hikonv::conv::reference::{conv2d_ref, ConvShape};
use hikonv::engine::conv2d_tiled;
use hikonv::exec::ThreadPool;
use hikonv::testing::assert_seq_eq;
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::rng::Rng;

fn gen_vec(rng: &mut Rng, bits: u32, signed: bool, len: usize) -> Vec<i64> {
    if signed {
        rng.quant_signed_vec(bits, len)
    } else {
        rng.quant_unsigned_vec(bits, len)
    }
}

fn signed_operands(sgn: Signedness) -> (bool, bool) {
    match sgn {
        Signedness::Unsigned => (false, false),
        Signedness::Signed => (true, true),
        Signedness::UnsignedBySigned => (false, true),
    }
}

/// `DotHiKonv::dot` equals the scalar dot product for every bitwidth pair
/// and signedness on the 32×32 CPU multiplier.
#[test]
fn dot_matches_reference_over_full_bitwidth_grid() {
    let mut rng = Rng::new(0x0D07);
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            for sgn in [
                Signedness::Unsigned,
                Signedness::Signed,
                Signedness::UnsignedBySigned,
            ] {
                let eng = match DotHiKonv::new(Multiplier::CPU32, p, q, sgn) {
                    Ok(e) => e,
                    // A signed 1-bit operand set ({-1, 0}) is degenerate;
                    // tolerate an infeasible solve only there.
                    Err(_) if matches!(sgn, Signedness::Signed) && p.min(q) == 1 => continue,
                    Err(e) => panic!("no dot design point for p={p} q={q} {sgn:?}: {e}"),
                };
                let (sx, sy) = signed_operands(sgn);
                for len in [1usize, 7, 63, 200] {
                    let x = gen_vec(&mut rng, p, sx, len);
                    let y = gen_vec(&mut rng, q, sy, len);
                    assert_eq!(
                        eng.dot(&x, &y),
                        dot_ref(&x, &y),
                        "p={p} q={q} {sgn:?} len={len}"
                    );
                }
            }
        }
    }
}

/// The tiled conv2d path equals `conv2d_ref` over the full `(p, q)` grid
/// and every signedness. The layer is below the small-layer serial
/// cutoff, so `conv2d_tiled` covers the serial route while an explicit
/// uneven `conv_co_range` split covers tile composition at every point.
#[test]
fn tiled_conv2d_matches_reference_over_full_bitwidth_grid() {
    let mut rng = Rng::new(0x711E);
    let pool = ThreadPool::new(3);
    let shape = ConvShape {
        ci: 3,
        co: 5,
        hi: 5,
        wi: 9,
        k: 3,
    };
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            for sgn in [
                Signedness::Unsigned,
                Signedness::Signed,
                Signedness::UnsignedBySigned,
            ] {
                let (sx, sw) = signed_operands(sgn);
                let input = gen_vec(&mut rng, p, sx, shape.input_len());
                let weights = gen_vec(&mut rng, q, sw, shape.weight_len());
                let spec = Conv2dSpec {
                    shape,
                    mult: Multiplier::CPU32,
                    p,
                    q,
                    signedness: sgn,
                };
                let eng = match Conv2dHiKonv::new(spec, &weights) {
                    Ok(e) => e,
                    Err(_) if matches!(sgn, Signedness::Signed) && p.min(q) == 1 => continue,
                    Err(e) => panic!("no conv2d design point for p={p} q={q} {sgn:?}: {e}"),
                };
                let want = conv2d_ref(&input, &weights, shape);
                assert_seq_eq(&conv2d_tiled(&eng, &pool, &input), &want)
                    .unwrap_or_else(|e| panic!("p={p} q={q} {sgn:?}: {e}"));
                // Uneven explicit tiles: 2 + 2 + 1 output channels.
                let packed = eng.pack_input(&input);
                let rows = shape.ho() * shape.wo();
                let mut out = vec![0i64; shape.output_len()];
                for (start, end) in [(0usize, 2usize), (2, 4), (4, 5)] {
                    eng.conv_co_range(&packed, start, end, &mut out[start * rows..end * rows]);
                }
                assert_seq_eq(&out, &want)
                    .unwrap_or_else(|e| panic!("tiles p={p} q={q} {sgn:?}: {e}"));
            }
        }
    }
}

/// Determinism: 1-thread and N-thread tiled outputs are bit-identical —
/// and identical to the serial engine — on a layer whose channel count
/// does not divide evenly into tiles (and which is large enough to take
/// the parallel path, not the small-layer serial cutoff).
#[test]
fn tiled_outputs_invariant_under_thread_count() {
    let shape = ConvShape {
        ci: 16,
        co: 13,
        hi: 8,
        wi: 30,
        k: 3,
    };
    assert!(shape.macs() >= 100_000, "shape too small to exercise tiling");
    let mut rng = Rng::new(0xDE7);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    let eng = Conv2dHiKonv::new(
        Conv2dSpec {
            shape,
            mult: Multiplier::CPU32,
            p: 4,
            q: 4,
            signedness: Signedness::UnsignedBySigned,
        },
        &weights,
    )
    .unwrap();
    let serial = eng.conv(&input);
    assert_seq_eq(&serial, &conv2d_ref(&input, &weights, shape)).unwrap();
    for threads in [1usize, 2, 3, 5, 8, 16] {
        let tiled = conv2d_tiled(&eng, &ThreadPool::new(threads), &input);
        assert_seq_eq(&tiled, &serial).unwrap_or_else(|e| panic!("threads={threads}: {e}"));
    }
}

/// The im2row lowering (now PackedGemm-backed) equals the reference
/// across the bitwidth diagonal — the FC-shaped reuse path (see
/// `tests/gemm_packed.rs` for the full GEMM property grid).
#[test]
fn im2row_matches_reference_across_bitwidths() {
    let mut rng = Rng::new(0x1280);
    let shape = ConvShape {
        ci: 2,
        co: 3,
        hi: 6,
        wi: 7,
        k: 3,
    };
    for bits in 1..=8u32 {
        for sgn in [Signedness::Unsigned, Signedness::UnsignedBySigned] {
            let (sx, sw) = signed_operands(sgn);
            let input = gen_vec(&mut rng, bits, sx, shape.input_len());
            let weights = gen_vec(&mut rng, bits, sw, shape.weight_len());
            let spec = Conv2dSpec {
                shape,
                mult: Multiplier::CPU32,
                p: bits,
                q: bits,
                signedness: sgn,
            };
            let eng = Im2RowConv::new(spec, &weights).unwrap();
            assert_seq_eq(&eng.conv(&input), &conv2d_ref(&input, &weights, shape))
                .unwrap_or_else(|e| panic!("bits={bits} {sgn:?}: {e}"));
        }
    }
}
