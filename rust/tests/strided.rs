//! Strided-window property suite: the strided im2row lowering and the
//! kernel subsample adapters vs the new strided reference oracle,
//! across the full (p, q) 1..=8 × signedness grid.

use hikonv::conv::conv2d::Conv2dSpec;
use hikonv::conv::im2row::Im2RowConv;
use hikonv::conv::reference::{conv2d_ref, conv2d_ref_strided, strided_out, ConvShape};
use hikonv::engine::{ConvKernel, EngineConfig, KernelRegistry};
use hikonv::models::ConvUnit;
use hikonv::testing::assert_seq_eq;
use hikonv::theory::{Multiplier, Signedness};
use hikonv::util::rng::Rng;

fn operand(rng: &mut Rng, bits: u32, len: usize, signed: bool) -> Vec<i64> {
    if signed {
        rng.quant_signed_vec(bits, len)
    } else {
        rng.quant_unsigned_vec(bits, len)
    }
}

/// Every (p, q) in 1..=8, every signedness, strides 1..=3: the strided
/// im2row lowering must equal the strided reference convolution.
#[test]
fn strided_conv2d_matches_reference_across_the_bitwidth_grid() {
    let mut rng = Rng::new(0x57A1D);
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            for signedness in [
                Signedness::Unsigned,
                Signedness::Signed,
                Signedness::UnsignedBySigned,
            ] {
                let shape = ConvShape {
                    ci: 2,
                    co: 3,
                    hi: 7,
                    wi: 9,
                    k: 3,
                };
                let signed_in = matches!(signedness, Signedness::Signed);
                let signed_w = !matches!(signedness, Signedness::Unsigned);
                let input = operand(&mut rng, p, shape.input_len(), signed_in);
                let weights = operand(&mut rng, q, shape.weight_len(), signed_w);
                let spec = Conv2dSpec {
                    shape,
                    mult: Multiplier::CPU32,
                    p,
                    q,
                    signedness,
                };
                for stride in 1..=3usize {
                    let eng = Im2RowConv::with_stride(spec, &weights, stride)
                        .unwrap_or_else(|e| panic!("p={p} q={q} {signedness:?}: {e}"));
                    let want = conv2d_ref_strided(&input, &weights, shape, stride);
                    assert_seq_eq(&eng.conv(&input), &want)
                        .unwrap_or_else(|e| panic!("p={p} q={q} {signedness:?} s={stride}: {e}"));
                }
            }
        }
    }
}

/// FC ops lower to k=1 units over a 1×1 spatial extent: the same grid,
/// checked against the dense reference (an FC is a pure matmul).
#[test]
fn fc_lowering_matches_reference_across_the_bitwidth_grid() {
    let mut rng = Rng::new(0xFC01);
    for p in 1..=8u32 {
        for q in 1..=8u32 {
            for signedness in [
                Signedness::Unsigned,
                Signedness::Signed,
                Signedness::UnsignedBySigned,
            ] {
                // Flattened 24-feature input, 5 output neurons.
                let shape = ConvShape {
                    ci: 24,
                    co: 5,
                    hi: 1,
                    wi: 1,
                    k: 1,
                };
                let signed_in = matches!(signedness, Signedness::Signed);
                let signed_w = !matches!(signedness, Signedness::Unsigned);
                let input = operand(&mut rng, p, shape.input_len(), signed_in);
                let weights = operand(&mut rng, q, shape.weight_len(), signed_w);
                let spec = Conv2dSpec {
                    shape,
                    mult: Multiplier::CPU32,
                    p,
                    q,
                    signedness,
                };
                let eng = Im2RowConv::new(spec, &weights)
                    .unwrap_or_else(|e| panic!("p={p} q={q} {signedness:?}: {e}"));
                let want = conv2d_ref(&input, &weights, shape);
                assert_seq_eq(&eng.conv(&input), &want)
                    .unwrap_or_else(|e| panic!("p={p} q={q} {signedness:?}: {e}"));
            }
        }
    }
}

/// Every registered kernel (including the dense-then-subsample hikonv
/// adapters) executes strided units bit-exactly, across bitwidths.
#[test]
fn every_registered_kernel_is_exact_on_strided_units() {
    let mut rng = Rng::new(0x57A2);
    for (p, q) in [(1u32, 1u32), (2, 3), (4, 4), (5, 2), (8, 8)] {
        let unit = ConvUnit {
            name: format!("s2-{p}x{q}"),
            ci: 3,
            co: 4,
            hi: 8,
            wi: 10,
            k: 3,
            stride: 2,
            pad: 1,
            a_bits: p,
            w_bits: q,
        };
        let cfg = EngineConfig::auto();
        let weights = rng.quant_signed_vec(q, unit.weight_len());
        let sh = unit.padded_shape();
        let input = rng.quant_unsigned_vec(p, sh.input_len());
        let want = conv2d_ref_strided(&input, &weights, sh, 2);
        assert_eq!(want.len(), unit.out_len());
        for f in KernelRegistry::builtin().entries() {
            f.supports(&unit, &cfg)
                .unwrap_or_else(|e| panic!("{} p={p} q={q}: {e}", f.name()));
            let kernel: Box<dyn ConvKernel> = f.build(&unit, &weights, &cfg).unwrap();
            assert_seq_eq(&kernel.conv(&input, None), &want)
                .unwrap_or_else(|e| panic!("{} p={p} q={q}: {e}", f.name()));
        }
    }
}

/// The oracle itself: strided output dims follow the floor formula and
/// stride 1 degenerates to the dense reference.
#[test]
fn strided_oracle_self_checks() {
    let shape = ConvShape {
        ci: 2,
        co: 2,
        hi: 11,
        wi: 6,
        k: 3,
    };
    assert_eq!(strided_out(shape, 1), (shape.ho(), shape.wo()));
    assert_eq!(strided_out(shape, 2), (5, 2));
    assert_eq!(strided_out(shape, 4), (3, 1));
    let mut rng = Rng::new(0x57A3);
    let input = rng.quant_unsigned_vec(4, shape.input_len());
    let weights = rng.quant_signed_vec(4, shape.weight_len());
    assert_eq!(
        conv2d_ref_strided(&input, &weights, shape, 1),
        conv2d_ref(&input, &weights, shape)
    );
}
