"""AOT lowering: every artifact lowers to parseable HLO text with the
expected entry signature (the contract the Rust runtime depends on)."""

import jax

jax.config.update("jax_enable_x64", True)

from compile import aot


def test_conv1d_artifacts_lower():
    text = aot.to_hlo_text(aot.lower_conv1d_hikonv())
    assert "ENTRY" in text
    assert "s32[4096]" in text  # input f
    assert "s32[3]" in text  # kernel g
    ref = aot.to_hlo_text(aot.lower_conv1d_ref())
    assert "ENTRY" in ref


def test_ultranet_tiny_lowers():
    text = aot.to_hlo_text(aot.lower_ultranet_tiny())
    assert "ENTRY" in text
    assert "s32[3,40,80]" in text
    assert "s32[36,5,10]" in text


def test_artifact_registry_complete():
    assert set(aot.ARTIFACTS) == {
        "hikonv_conv1d.hlo.txt",
        "ref_conv1d.hlo.txt",
        "ultranet_tiny.hlo.txt",
        "ultranet.hlo.txt",
    }
