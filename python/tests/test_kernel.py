"""L1 kernel correctness: Pallas HiKonv conv vs the pure-jnp oracle.

Hypothesis sweeps shapes and bitwidths — the core correctness signal for
the compile path (mirrors rust/src/conv/conv1d.rs property tests).
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hikonv
from compile.kernels.design import solve_unsigned
from compile.kernels.ref import conv1d_ref


def random_levels(rng, bits, n):
    return jnp.asarray(
        rng.integers(0, 2**bits, size=n, dtype=np.int64), dtype=jnp.int32
    )


def test_paper_cpu_design_point():
    dp = solve_unsigned(32, 32, 4, 4)
    assert (dp.s, dp.n, dp.k, dp.gb) == (10, 3, 3, 2)
    assert dp.ops_per_mult == 13


def test_dsp_design_points():
    dp = solve_unsigned(27, 18, 4, 4)
    assert (dp.s, dp.n, dp.k) == (9, 3, 2)
    assert dp.ops_per_mult == 8
    # strict binary optimum (DESIGN.md §3)
    dp1 = solve_unsigned(27, 18, 1, 1)
    assert dp1.ops_per_mult == 94


def test_pack_word_matches_definition():
    vals = jnp.asarray([3, 5, 1], dtype=jnp.int32)
    assert int(hikonv.pack_word(vals, 4)) == 3 + 5 * 16 + 256


def test_4bit_kernel_matches_reference():
    rng = np.random.default_rng(0)
    f = random_levels(rng, 4, 1000)
    g = random_levels(rng, 4, 3)
    got = hikonv.hikonv_conv1d_4bit(f, g)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_worst_case_guard_bits():
    dp = solve_unsigned(32, 32, 4, 4)
    f = jnp.full((500,), 15, dtype=jnp.int32)
    g = jnp.full((3,), 15, dtype=jnp.int32)
    got = hikonv.hikonv_conv1d(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    bits=st.integers(min_value=1, max_value=8),
    flen=st.integers(min_value=1, max_value=700),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_kernel_matches_reference(bits, flen, seed):
    dp = solve_unsigned(32, 32, bits, bits)
    rng = np.random.default_rng(seed)
    f = random_levels(rng, bits, flen)
    glen = rng.integers(1, dp.k + 1)
    g = random_levels(rng, bits, glen)
    got = hikonv.hikonv_conv1d(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    bits=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_dsp48e2_points(bits, seed):
    """The 27x18 DSP design points also hold on the lane-packed kernel."""
    dp = solve_unsigned(27, 18, bits, bits)
    rng = np.random.default_rng(seed)
    f = random_levels(rng, bits, 300)
    g = random_levels(rng, bits, dp.k)
    got = hikonv.hikonv_conv1d(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_input_shorter_than_one_chunk():
    dp = solve_unsigned(32, 32, 4, 4)
    f = jnp.asarray([7, 2], dtype=jnp.int32)
    g = jnp.asarray([3, 1, 5], dtype=jnp.int32)
    got = hikonv.hikonv_conv1d(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_longer_than_k_rejected():
    dp = solve_unsigned(32, 32, 4, 4)
    f = jnp.zeros(16, dtype=jnp.int32)
    g = jnp.zeros(dp.k + 1, dtype=jnp.int32)
    with pytest.raises(AssertionError):
        hikonv.hikonv_conv1d(f, g, dp)


def random_signed_levels(rng, bits, n):
    lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1)
    return jnp.asarray(rng.integers(lo, hi, size=n, dtype=np.int64), dtype=jnp.int32)


def test_signed_design_point_has_sign_headroom():
    from compile.kernels.design import solve_signed

    dp = solve_signed(32, 32, 4, 4)
    # Signed 4-bit needs one more slice bit than unsigned at equal terms.
    assert dp.s >= 10
    assert dp.n >= 2 and dp.k >= 2


def test_signed_kernel_matches_reference():
    from compile.kernels.design import solve_signed

    dp = solve_signed(32, 32, 4, 4)
    rng = np.random.default_rng(7)
    f = random_signed_levels(rng, 4, 777)
    g = random_signed_levels(rng, 4, dp.k)
    got = hikonv.hikonv_conv1d_signed(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_signed_worst_case_extremes():
    from compile.kernels.design import solve_signed

    dp = solve_signed(32, 32, 4, 4)
    f = jnp.full((300,), -8, dtype=jnp.int32)
    g = jnp.full((dp.k,), -8, dtype=jnp.int32)
    got = hikonv.hikonv_conv1d_signed(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    bits=st.integers(min_value=2, max_value=7),
    flen=st.integers(min_value=1, max_value=400),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_signed_kernel_matches_reference(bits, flen, seed):
    from compile.kernels.design import solve_signed

    dp = solve_signed(32, 32, bits, bits)
    rng = np.random.default_rng(seed)
    f = random_signed_levels(rng, bits, flen)
    glen = rng.integers(1, dp.k + 1)
    g = random_signed_levels(rng, bits, glen)
    got = hikonv.hikonv_conv1d_signed(f, g, dp)
    want = conv1d_ref(f, g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
