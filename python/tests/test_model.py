"""L2 model correctness: Pallas conv2d vs oracle, UltraNet shapes/determinism."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.conv2d import conv2d, int_matmul
from compile.kernels.ref import conv2d_ref, maxpool2_ref, requantize_ref


def test_int_matmul_matches_jnp():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(0, 16, size=(50, 27), dtype=np.int64), jnp.int32)
    w = jnp.asarray(rng.integers(-8, 8, size=(27, 20), dtype=np.int64), jnp.int32)
    got = int_matmul(x, w)
    want = (x.astype(jnp.int64) @ w.astype(jnp.int64)).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    ci=st.integers(min_value=1, max_value=8),
    co=st.integers(min_value=1, max_value=8),
    h=st.integers(min_value=3, max_value=10),
    w=st.integers(min_value=3, max_value=12),
    k=st.sampled_from([1, 3]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_property_conv2d_matches_oracle(ci, co, h, w, k, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 16, size=(ci, h, w), dtype=np.int64), jnp.int32)
    wts = jnp.asarray(
        rng.integers(-8, 8, size=(co, ci, k, k), dtype=np.int64), jnp.int32
    )
    got = conv2d(x, wts, pad=k // 2)
    want = conv2d_ref(x, wts, pad=k // 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_requantize_and_pool():
    acc = jnp.asarray([[-3, 40], [16, 7]], dtype=jnp.int32).reshape(1, 2, 2)
    q = requantize_ref(acc, 1, 4)
    assert q.max() <= 15 and q.min() >= 0
    pooled = maxpool2_ref(q)
    assert pooled.shape == (1, 1, 1)
    assert int(pooled[0, 0, 0]) == 15  # clip(40>>1)=15


def test_ultranet_tiny_shapes_and_determinism():
    rng = np.random.default_rng(3)
    frame = jnp.asarray(
        rng.integers(0, 16, size=model.ULTRANET_TINY_INPUT, dtype=np.int64),
        jnp.int32,
    )
    out1 = model.ultranet_tiny_forward(frame)[0]
    out2 = model.ultranet_tiny_forward(frame)[0]
    assert out1.shape == (36, 5, 10)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert np.asarray(out1).any(), "all-zero head output is suspicious"


def test_ultranet_layer_table_is_consistent():
    # chained channel counts
    for (prev, nxt) in zip(model.ULTRANET_LAYERS, model.ULTRANET_LAYERS[1:]):
        assert prev[2] == nxt[1], f"{prev} -> {nxt}"
    assert model.ULTRANET_LAYERS[0][1] == model.ULTRANET_INPUT[0]
    # total MACs match the Rust model's pinned value
    c, h, w = model.ULTRANET_INPUT
    total = 0
    for (_, ci, co, k, pool) in model.ULTRANET_LAYERS:
        total += co * h * w * ci * k * k
        if pool:
            h, w = h // 2, w // 2
    assert total == 199_526_400, total
