"""AOT compile path: lower the L2 graphs (with L1 Pallas kernels inlined)
to HLO **text** artifacts the Rust runtime loads via PJRT.

HLO text, not serialized protos: jax >= 0.5 emits 64-bit instruction ids
that xla_extension 0.5.1 rejects; the text parser reassigns ids
(/opt/xla-example/README.md). Runs once at build time (`make artifacts`).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # packed words are int64 lanes

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import hikonv  # noqa: E402
from .kernels.design import solve_unsigned  # noqa: E402
from .kernels.ref import conv1d_ref  # noqa: E402

# Fixed shapes for the standalone conv1d artifacts.
CONV1D_LEN = 4096
CONV1D_TAPS = 3


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_conv1d_hikonv():
    dp = solve_unsigned(32, 32, 4, 4)

    def fn(f, g):
        return (hikonv.hikonv_conv1d(f, g, dp),)

    spec_f = jax.ShapeDtypeStruct((CONV1D_LEN,), jnp.int32)
    spec_g = jax.ShapeDtypeStruct((CONV1D_TAPS,), jnp.int32)
    return jax.jit(fn).lower(spec_f, spec_g)


def lower_conv1d_ref():
    def fn(f, g):
        return (conv1d_ref(f, g),)

    spec_f = jax.ShapeDtypeStruct((CONV1D_LEN,), jnp.int32)
    spec_g = jax.ShapeDtypeStruct((CONV1D_TAPS,), jnp.int32)
    return jax.jit(fn).lower(spec_f, spec_g)


def lower_ultranet():
    spec = jax.ShapeDtypeStruct(model.ULTRANET_INPUT, jnp.int32)
    return jax.jit(model.ultranet_forward).lower(spec)


def lower_ultranet_tiny():
    spec = jax.ShapeDtypeStruct(model.ULTRANET_TINY_INPUT, jnp.int32)
    return jax.jit(model.ultranet_tiny_forward).lower(spec)


ARTIFACTS = {
    "hikonv_conv1d.hlo.txt": lower_conv1d_hikonv,
    "ref_conv1d.hlo.txt": lower_conv1d_ref,
    "ultranet_tiny.hlo.txt": lower_ultranet_tiny,
    "ultranet.hlo.txt": lower_ultranet,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="build a single artifact by filename"
    )
    # Back-compat with the scaffold Makefile (--out <file> builds everything
    # into that file's directory).
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    for name, build in ARTIFACTS.items():
        if args.only and name != args.only:
            continue
        text = to_hlo_text(build())
        path = os.path.join(out_dir, name)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {len(text):>10} chars  {path}")


if __name__ == "__main__":
    main()
