"""HiKonv design-point solver (Python mirror of rust/src/theory/solver.rs).

Unsigned operands only on this side (the L1 kernels process unsigned
activation/weight levels; signed handling lives in the Rust engines).
Values pinned against the Rust solver in python/tests/test_design.py.
"""

from dataclasses import dataclass


def bits_for(v: int) -> int:
    """Number of bits to represent non-negative v (bits_for(0) == 1)."""
    return max(v.bit_length(), 1)


@dataclass(frozen=True)
class DesignPoint:
    bit_a: int
    bit_b: int
    p: int
    q: int
    m: int  # accumulation depth (Extended mode, m*K products per segment)
    s: int
    n: int
    k: int

    @property
    def gb(self) -> int:
        base = self.q if self.p == 1 else (self.p if self.q == 1 else self.p + self.q)
        return self.s - base

    @property
    def ops_per_mult(self) -> int:
        return self.n * self.k + (self.n - 1) * (self.k - 1)

    @property
    def segments(self) -> int:
        return self.n + self.k - 1


def solve_unsigned(
    bit_a: int, bit_b: int, p: int, q: int, m: int = 1, lane_bits: int = 63
) -> DesignPoint:
    """Throughput-maximal design point under Eqs. 6-8 with exact guard
    sizing, Extended accumulation (m*K products per segment).

    `lane_bits` is the TPU adaptation (DESIGN.md §Hardware-Adaptation): the
    packed product lives in a signed int64 lane, so the S*(N+K-1) product
    bits must fit 63 bits. This only affects p=q=2 on a 32x32 multiplier
    (N=K=6 -> N=K=5); every point the paper evaluates is unchanged.
    """
    assert 1 <= p <= bit_a and 1 <= q <= bit_b
    best = None
    for s in range(1, bit_a + bit_b + 1):
        n = (bit_a - p) // s + 1
        k = (bit_b - q) // s + 1
        terms = m * k
        required = bits_for(terms * (2**p - 1) * (2**q - 1))
        if s < required:
            continue
        if s * (n + k - 1) > lane_bits:
            continue
        dp = DesignPoint(bit_a, bit_b, p, q, m, s, n, k)
        key = (dp.ops_per_mult, -s, n)
        if best is None or key > best[0]:
            best = (key, dp)
        if n == 1 and k == 1:
            break
    assert best is not None, "no feasible slice width"
    return best[1]


def solve_signed(
    bit_a: int, bit_b: int, p: int, q: int, m: int = 1, lane_bits: int = 63
) -> DesignPoint:
    """Signed-operand design point: segments hold two's-complement partial
    sums, so S covers the worst-case magnitude plus a sign bit (mirrors
    rust/src/theory/solver.rs `Signedness::Signed`)."""
    assert 1 <= p <= bit_a and 1 <= q <= bit_b
    best = None
    maxmag = 2 ** (p - 1) * 2 ** (q - 1)
    for s in range(1, bit_a + bit_b + 1):
        n = (bit_a - p) // s + 1
        k = (bit_b - q) // s + 1
        required = bits_for(m * k * maxmag) + 1
        if s < required:
            continue
        if s * (n + k - 1) > lane_bits:
            continue
        dp = DesignPoint(bit_a, bit_b, p, q, m, s, n, k)
        key = (dp.ops_per_mult, -s, n)
        if best is None or key > best[0]:
            best = (key, dp)
        if n == 1 and k == 1:
            break
    assert best is not None, "no feasible slice width"
    return best[1]
