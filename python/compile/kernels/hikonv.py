"""L1 Pallas kernel: the HiKonv packed 1-D convolution (Theorems 1-2).

TPU adaptation of the paper's bit-management (DESIGN.md §Hardware-
Adaptation): quantized operands are *lane-packed* into wide integer words
in VMEM, one wide multiply per `F_{N,K}` block replaces N·K MACs, and the
product is segmented back into convolution outputs. BlockSpec tiles the
chunk axis so HBM<->VMEM traffic moves packed words (~1/N of the unpacked
bytes).

interpret=True always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what
`aot.py` exports for the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .design import DesignPoint, solve_unsigned

# Chunk-axis tile for the packed-multiply kernel (VMEM-sized: 256 packed
# words x (N + segments) int64 lanes stays far under typical VMEM budgets).
BLOCK_X = 256


def pack_word(vals, s: int):
    """Pack a trailing axis of unsigned values into one int64 word each:
    `A = sum v[i] * 2^(S*i)` (Eq. 11)."""
    n = vals.shape[-1]
    powers = (jnp.int64(1) << (s * jnp.arange(n, dtype=jnp.int64)))
    return jnp.sum(vals.astype(jnp.int64) * powers, axis=-1)


def _fnk_kernel(chunks_ref, b_ref, segs_ref, *, s: int, n: int, nseg: int):
    """Pallas body: pack N-value chunks, one wide multiply against the packed
    kernel word, segment the product (Thm. 1)."""
    chunks = chunks_ref[...].astype(jnp.int64)  # (bx, N)
    powers = (jnp.int64(1) << (s * jnp.arange(n, dtype=jnp.int64)))
    a = jnp.sum(chunks * powers[None, :], axis=1)  # (bx,)
    prod = a * b_ref[0]  # the single wide multiplication
    mask = (jnp.int64(1) << s) - 1
    segs = [(prod >> (s * j)) & mask for j in range(nseg)]
    segs_ref[...] = jnp.stack(segs, axis=1).astype(jnp.int32)


def fnk_segments(chunks, packed_g, dp: DesignPoint):
    """Run the packed-multiply kernel over all chunks: (X, N) int32 chunks ->
    (X, N+K-1) int32 convolution segments."""
    x = chunks.shape[0]
    nseg = dp.segments
    grid = (pl.cdiv(x, BLOCK_X),)
    kernel = functools.partial(_fnk_kernel, s=dp.s, n=dp.n, nseg=nseg)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_X, dp.n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_X, nseg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x, nseg), jnp.int32),
        interpret=True,
    )(chunks, packed_g)


def hikonv_conv1d(f, g, dp: DesignPoint):
    """Full HiKonv 1-D convolution `f * g` (unsigned levels).

    `g` must have at most K taps (kernel chunking for longer filters lives
    in the Rust engine; the DNN kernels the model uses are 1x1/3x3 rows).
    Returns len(f) + len(g) - 1 outputs, int32.
    """
    l = f.shape[0]
    glen = g.shape[0]
    assert glen <= dp.k, f"kernel of {glen} taps exceeds K={dp.k}"
    xchunks = -(-l // dp.n)  # ceil
    fpad = jnp.pad(f, (0, xchunks * dp.n - l))
    chunks = fpad.reshape(xchunks, dp.n)
    packed_g = pack_word(g, dp.s).reshape(1)
    segs = fnk_segments(chunks, packed_g, dp)
    # Overlap-add (Thm. 2): y[x*N + j] += segs[x, j].
    y = jnp.zeros(xchunks * dp.n + dp.k - 1, dtype=jnp.int32)
    xs = dp.n * jnp.arange(xchunks)
    for j in range(dp.segments):
        y = y.at[xs + j].add(segs[:, j])
    return y[: l + glen - 1]


def hikonv_conv1d_4bit(f, g):
    """The paper's CPU design point (32x32, p=q=4): S=10, N=3, K=3."""
    dp = solve_unsigned(32, 32, 4, 4)
    assert (dp.s, dp.n, dp.k) == (10, 3, 3)
    return hikonv_conv1d(f, g, dp)


def _fnk_kernel_signed(chunks_ref, b_ref, segs_ref, *, s: int, n: int, nseg: int):
    """Signed Pallas body: Eq.-13 segmentation — sign-extend each S-bit
    field and add back the carry bit just below it."""
    chunks = chunks_ref[...].astype(jnp.int64)
    powers = (jnp.int64(1) << (s * jnp.arange(n, dtype=jnp.int64)))
    # Wrapping sum == Eq.-13 borrow recursion (packing mod 2^64).
    a = jnp.sum(chunks * powers[None, :], axis=1)
    prod = a * b_ref[0]
    mask = (jnp.int64(1) << s) - 1
    sign = jnp.int64(1) << (s - 1)
    segs = []
    for j in range(nseg):
        raw = (prod >> (s * j)) & mask
        se = raw - ((raw & sign) << 1)  # sign-extend S bits
        carry = ((prod >> (s * j - 1)) & 1) if j > 0 else jnp.int64(0)
        segs.append(se + carry)
    segs_ref[...] = jnp.stack(segs, axis=1).astype(jnp.int32)


def fnk_segments_signed(chunks, packed_g, dp: DesignPoint):
    """Signed variant of `fnk_segments`."""
    x = chunks.shape[0]
    nseg = dp.segments
    grid = (pl.cdiv(x, BLOCK_X),)
    kernel = functools.partial(_fnk_kernel_signed, s=dp.s, n=dp.n, nseg=nseg)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_X, dp.n), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_X, nseg), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x, nseg), jnp.int32),
        interpret=True,
    )(chunks, packed_g)


def hikonv_conv1d_signed(f, g, dp: DesignPoint):
    """Signed HiKonv 1-D convolution (two's-complement levels; Eq. 13).

    Mirrors `hikonv_conv1d`; the design point must come from
    `design.solve_signed` so the slices carry a sign bit.
    """
    l = f.shape[0]
    glen = g.shape[0]
    assert glen <= dp.k, f"kernel of {glen} taps exceeds K={dp.k}"
    xchunks = -(-l // dp.n)
    fpad = jnp.pad(f, (0, xchunks * dp.n - l))
    chunks = fpad.reshape(xchunks, dp.n)
    packed_g = pack_word(g, dp.s).reshape(1)
    segs = fnk_segments_signed(chunks, packed_g, dp)
    y = jnp.zeros(xchunks * dp.n + dp.k - 1, dtype=jnp.int32)
    xs = dp.n * jnp.arange(xchunks)
    for j in range(dp.segments):
        y = y.at[xs + j].add(segs[:, j])
    return y[: l + glen - 1]
