"""HiKonv L1 kernels (Pallas) and their pure-jnp oracles."""
