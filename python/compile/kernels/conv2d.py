"""L1 Pallas kernel: quantized conv2d as an im2col x packed-MXU matmul.

The UltraNet layers (L2) call this. The matmul accumulates int32 levels;
blocking follows MXU-friendly tiles (128x128 output blocks with the full
contraction axis resident — UltraNet contractions are at most 64*9=576
lanes, comfortably VMEM-sized).

interpret=True as everywhere (see hikonv.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import im2col

BLOCK_M = 128
BLOCK_N = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.int32
    )


def int_matmul(x, w):
    """(M, C) int32 x (C, N) int32 -> (M, N) int32 via a Pallas matmul."""
    m, c = x.shape
    c2, n = w.shape
    assert c == c2
    grid = (pl.cdiv(m, BLOCK_M), pl.cdiv(n, BLOCK_N))
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_M, c), lambda i, j: (i, 0)),
            pl.BlockSpec((c, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(x, w)


def conv2d(x, wts, pad: int):
    """Quantized conv layer: x (Ci, H, W) int32, wts (Co, Ci, k, k) int32,
    same padding, stride 1 -> (Co, H, W) int32 accumulators."""
    co, ci, k, _ = wts.shape
    _, h, w = x.shape
    patches = im2col(x, k, pad).astype(jnp.int32)  # (H*W, Ci*k*k)
    wmat = wts.reshape(co, ci * k * k).T.astype(jnp.int32)  # (Ci*k*k, Co)
    out = int_matmul(patches, wmat)  # (H*W, Co)
    return out.T.reshape(co, h, w)
