"""Pure-jnp correctness oracles for the L1 kernels.

These are the ground truth every Pallas kernel is pytest-checked against
(the same role `conv/reference.rs` plays for the Rust engines).
"""

import jax.numpy as jnp


def conv1d_ref(f, g):
    """Full 1-D convolution (Eq. 3): len(f) + len(g) - 1 outputs, int32."""
    return jnp.convolve(
        f.astype(jnp.int64), g.astype(jnp.int64), mode="full"
    ).astype(jnp.int32)


def im2col(x, k: int, pad: int):
    """Unfold (C, H, W) into (H*W, C*k*k) patches for a same-padded
    k x k convolution (stride 1)."""
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(xp[:, dy : dy + h, dx : dx + w])
    patches = jnp.stack(cols, axis=1)  # (C, k*k, H, W)
    return patches.reshape(c * k * k, h * w).T


def conv2d_ref(x, wts, pad: int):
    """Quantized conv layer oracle: x (Ci, H, W) int, wts (Co, Ci, k, k) int.
    Same padding, stride 1. Returns (Co, H, W) int32 accumulators."""
    co, ci, k, _ = wts.shape
    _, h, w = x.shape
    patches = im2col(x, k, pad).astype(jnp.int64)  # (H*W, Ci*k*k)
    wmat = wts.reshape(co, ci * k * k).astype(jnp.int64)  # (Co, Ci*k*k)
    out = patches @ wmat.T  # (H*W, Co)
    return out.T.reshape(co, h, w).astype(jnp.int32)


def requantize_ref(acc, shift: int, bits: int):
    """ReLU + right-shift requantization to unsigned `bits` levels."""
    hi = (1 << bits) - 1
    return jnp.clip(jnp.maximum(acc, 0) >> shift, 0, hi)


def maxpool2_ref(x):
    """2x2 max pool (stride 2) over (C, H, W)."""
    c, h, w = x.shape
    return x.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))
