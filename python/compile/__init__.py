"""HiKonv compile path (build-time only; never imported at runtime)."""
