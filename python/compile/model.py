"""L2: UltraNet forward graphs in JAX, calling the L1 Pallas kernels.

Weights are synthetic (seeded numpy) and baked into the graph as constants
so the AOT artifact is self-contained — the Rust serving path feeds only
the quantized frame. Architecture mirrors rust/src/models/ultranet.rs.
"""

import numpy as np
import jax.numpy as jnp

from .kernels.conv2d import conv2d
from .kernels.ref import maxpool2_ref, requantize_ref

# (name, ci, co, k, pool_after). Spatial dims follow from the input.
ULTRANET_LAYERS = [
    ("conv1", 3, 16, 3, True),
    ("conv2", 16, 32, 3, True),
    ("conv3", 32, 64, 3, True),
    ("conv4", 64, 64, 3, True),
    ("conv5", 64, 64, 3, False),
    ("conv6", 64, 64, 3, False),
    ("conv7", 64, 64, 3, False),
    ("conv8", 64, 64, 3, False),
    ("head", 64, 36, 1, False),
]

ULTRANET_TINY_LAYERS = [
    ("conv1", 3, 16, 3, True),
    ("conv2", 16, 32, 3, True),
    ("conv3", 32, 64, 3, True),
    ("conv4", 64, 64, 3, False),
    ("head", 64, 36, 1, False),
]

ULTRANET_INPUT = (3, 160, 320)
ULTRANET_TINY_INPUT = (3, 40, 80)

A_BITS = 4
W_BITS = 4


def synthetic_weights(layers, seed: int):
    """Seeded signed 4-bit weights for every layer."""
    rng = np.random.default_rng(seed)
    lo, hi = -(2 ** (W_BITS - 1)), 2 ** (W_BITS - 1) - 1
    return [
        rng.integers(lo, hi + 1, size=(co, ci, k, k), dtype=np.int32)
        for (_, ci, co, k, _) in layers
    ]


def _np_conv2d(x, wts, pad):
    """Pure-numpy same-padded conv (calibration only — numpy keeps this
    immune to an enclosing jit trace)."""
    co, ci, k, _ = wts.shape
    _, h, w = x.shape
    xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    cols = [xp[:, dy : dy + h, dx : dx + w] for dy in range(k) for dx in range(k)]
    patches = np.stack(cols, axis=1).reshape(ci * k * k, h * w)
    out = wts.reshape(co, ci * k * k).astype(np.int64) @ patches.astype(np.int64)
    return out.reshape(co, h, w)


def calibrate_shifts(layers, weights, input_shape, seed: int = 99):
    """Per-layer requantization shifts: run one random frame and size each
    shift so the layer's max accumulator maps into the 4-bit activation
    range (mirrors the Rust runner's calibration pass). Numpy-only; the
    shifts become constants in the AOT graph."""
    rng = np.random.default_rng(seed)
    act = rng.integers(0, 2**A_BITS, size=input_shape, dtype=np.int64)
    shifts = []
    target = (1 << A_BITS) - 1
    for i, ((_name, _ci, _co, k, pool), wts) in enumerate(zip(layers, weights)):
        acc = _np_conv2d(act, np.asarray(wts), pad=k // 2)
        maxacc = int(np.abs(acc).max())
        shift = 0
        while (maxacc >> shift) > target:
            shift += 1
        shifts.append(shift)
        if i + 1 < len(layers):
            act = np.clip(np.maximum(acc, 0) >> shift, 0, target)
            if pool:
                c, h, w = act.shape
                act = act.reshape(c, h // 2, 2, w // 2, 2).max(axis=(2, 4))
    return shifts


_SHIFT_CACHE = {}


def _shifts_for(name, layers, weights, input_shape):
    if name not in _SHIFT_CACHE:
        _SHIFT_CACHE[name] = calibrate_shifts(layers, weights, input_shape)
    return _SHIFT_CACHE[name]


def forward(frame, layers, weights, shifts):
    """Quantized forward pass: frame (C, H, W) int32 4-bit levels ->
    head accumulators (36, H', W') int32."""
    act = frame.astype(jnp.int32)
    for (i, ((_, _ci, _co, k, pool), wts)) in enumerate(zip(layers, weights)):
        acc = conv2d(act, jnp.asarray(wts), pad=k // 2)
        if i + 1 == len(layers):
            return acc
        act = requantize_ref(acc, shifts[i], A_BITS).astype(jnp.int32)
        if pool:
            act = maxpool2_ref(act)
    return act


def ultranet_forward(frame):
    """Full UltraNet: (3, 160, 320) int32 -> (36, 10, 20) int32 tuple."""
    weights = synthetic_weights(ULTRANET_LAYERS, seed=2020)
    shifts = _shifts_for("ultranet", ULTRANET_LAYERS, weights, ULTRANET_INPUT)
    return (forward(frame, ULTRANET_LAYERS, weights, shifts),)


def ultranet_tiny_forward(frame):
    """UltraNet-tiny: (3, 40, 80) int32 -> (36, 5, 10) int32 tuple."""
    weights = synthetic_weights(ULTRANET_TINY_LAYERS, seed=2020)
    shifts = _shifts_for(
        "ultranet_tiny", ULTRANET_TINY_LAYERS, weights, ULTRANET_TINY_INPUT
    )
    return (forward(frame, ULTRANET_TINY_LAYERS, weights, shifts),)
